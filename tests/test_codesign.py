"""Hardware/mapping co-design sweep (beyond-paper, core/codesign.py)."""
import pytest

from repro.core.codesign import (DesignPoint, area_proxy, evaluate_design,
                                 pareto_frontier, sweep)
from repro.core.hardware import EYERISS_LIKE
from repro.core.workloads import QWEN3_0_6B


def test_area_proxy_monotone():
    a = area_proxy(256, 162 * 1024, 424)
    assert area_proxy(512, 162 * 1024, 424) > a
    assert area_proxy(256, 324 * 1024, 424) > a
    assert area_proxy(256, 162 * 1024, 848) > a


@pytest.mark.slow    # full exact solves over the design grid, ~17s
def test_small_sweep_and_frontier():
    pts = sweep(EYERISS_LIKE, QWEN3_0_6B, 1024,
                pe_opts=(64, 256), sram_kib_opts=(64, 162),
                rf_opts=(64, 424))
    assert len(pts) == 8
    assert any(p.feasible for p in pts)
    front = pareto_frontier(pts)
    assert front, "frontier must be non-empty"
    # frontier is sorted by area and strictly improving in EDP
    for a, b in zip(front, front[1:]):
        assert b.area > a.area and b.edp < a.edp
    # no feasible point dominates a frontier point
    for f in front:
        for p in pts:
            if p.feasible:
                assert not (p.area < f.area and p.edp < f.edp)


def test_more_pe_helps_big_gemm():
    """On a compute-heavy workload, quadrupling PEs cuts delay-driven EDP."""
    from repro.core.workloads import prefill_gemms
    wl = [w for w in prefill_gemms(QWEN3_0_6B, 1024)
          if w[0] == "mlp_gate_up"]
    small = evaluate_design(EYERISS_LIKE, 64, 162 * 1024, 424, wl)
    big = evaluate_design(EYERISS_LIKE, 1024, 162 * 1024, 424, wl)
    assert small.feasible and big.feasible
    assert big.edp < small.edp
