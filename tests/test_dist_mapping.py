"""Mesh-level GOMA extension (core/dist_mapping.py): the walking-axis
geometry ranks sharding choices by ICI traffic."""
from repro.core import Gemm
from repro.core.dist_mapping import plan_shard_axis, recommend


def test_tall_gemm_prefers_row_sharding():
    # M >> N, K: B is tiny -> data parallel (x-walk) is cheapest
    g = Gemm(1_000_000, 1024, 1024)
    best = recommend(g, 16)
    assert best.axis == "x"


def test_wide_gemm_prefers_col_sharding():
    # N >> M, K: A is tiny -> tensor parallel (y-walk) is cheapest
    g = Gemm(1024, 1_000_000, 1024)
    best = recommend(g, 16)
    assert best.axis == "y"


def test_deep_reduction_prefers_z_sharding():
    # K >> M, N: P is tiny -> reduction parallel (reduce-scatter) wins,
    # GOMA's rho boundary case at mesh scale
    g = Gemm(1024, 1024, 1_000_000)
    best = recommend(g, 16)
    assert best.axis == "z"
    assert "reduce-scatter" in best.collective


def test_ranking_is_complete_and_sorted():
    g = Gemm(4096, 14336, 4096)
    choices = plan_shard_axis(g, 256, with_backward=True)
    assert [c.axis for c in choices] != []
    assert len(choices) == 3
    assert all(choices[i].ici_bytes_per_chip
               <= choices[i + 1].ici_bytes_per_chip
               for i in range(2))
    # backward doubles-ish the traffic
    fwd = plan_shard_axis(g, 256, with_backward=False)
    assert choices[0].ici_bytes_per_chip >= fwd[0].ici_bytes_per_chip