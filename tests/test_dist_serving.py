"""Sharded serving integration via subprocess (4 fake CPU devices), so
the main test session keeps the default single device."""
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow    # subprocess with 4 fake devices

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_dist_serve_smoke():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "dist_serve_smoke.py")],
        capture_output=True, text=True, timeout=880)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    for marker in ("TOKENS_OK", "PREWARM_OK", "SCHED_OK", "ALL_OK"):
        assert marker in proc.stdout, proc.stdout
