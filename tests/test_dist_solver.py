"""Joint (mesh, tiling) solver: brute-force differential oracle on tiny
meshes, certificate verification, partition specs, and the sharded plan
store round-trip.  Core-only — no jax required."""
import dataclasses
import math

import pytest

from repro.core import TEMPLATES
from repro.core.dist_mapping import (collective_energy, collective_words,
                                     plan_shard_axis)
from repro.core.fusion import link_energy
from repro.core.geometry import Gemm
from repro.core.solver import solve, solver_stats
from repro.dist import (MeshSpec, enumerate_partitions, partition_specs,
                        solve_sharded, verify_sharded)
from repro.dist.mesh_solve import sub_gemm
from repro.planner.batch import cached_solve_sharded
from repro.planner.store import (PlanStore, ShardedPlanEntry,
                                 sharded_certificate_from_json,
                                 sharded_certificate_to_json,
                                 sharded_plan_key)

ORACLE_GEMMS = [Gemm(8, 8, 8, "cube8"), Gemm(12, 4, 6, "ragged"),
                Gemm(16, 32, 8, "wide")]
ORACLE_HW = ("eyeriss-like", "gemmini-like")
ORACLE_CHIPS = (1, 2, 3, 4)


def _brute_force(gemm, hw, n_chips, dtype_bytes=1):
    """Independent re-derivation of the joint optimum: enumerate every
    divisor-respecting factorization, solve each sub-problem exactly,
    price collectives in closed form, take the min."""
    best = math.inf
    best_counts = None
    for counts in enumerate_partitions(gemm, n_chips):
        sub = sub_gemm(gemm, counts)
        res = solve(sub, hw, objective="energy")
        if res.mapping is None:
            continue
        total = (link_energy(sub, res.mapping, hw)
                 + collective_energy(gemm, counts, hw,
                                     dtype_bytes=dtype_bytes))
        if total < best:
            best, best_counts = total, counts
    return best, best_counts


@pytest.mark.parametrize("hw_name", ORACLE_HW)
@pytest.mark.parametrize("gemm", ORACLE_GEMMS, ids=lambda g: g.name)
@pytest.mark.parametrize("n_chips", ORACLE_CHIPS)
def test_joint_matches_brute_force(gemm, hw_name, n_chips):
    hw = TEMPLATES[hw_name]
    res = solve_sharded(gemm, hw, n_chips)
    c = res.certificate
    want, _ = _brute_force(gemm, hw, n_chips)
    if want == math.inf:
        assert not c.feasible
        return
    assert c.feasible
    assert c.objective == pytest.approx(want, rel=1e-12)
    assert c.gap == 0.0
    assert c.upper_bound == c.lower_bound == c.objective
    assert c.objective == pytest.approx(c.chip_pj + c.collective_pj,
                                        rel=1e-12)
    # independent composition is an enumerated branch -> joint <= it
    if c.independent_objective != math.inf:
        assert c.objective <= c.independent_objective * (1 + 1e-12)
    assert verify_sharded(c, hw, res.mapping)


def test_single_chip_degenerates_to_chip_energy():
    hw = TEMPLATES["gemmini-like"]
    gemm = Gemm(16, 16, 16, "one")
    res = solve_sharded(gemm, hw, 1)
    c = res.certificate
    assert c.counts == (1, 1, 1)
    assert c.collective_pj == 0.0
    chip = solve(gemm, hw, objective="energy")
    assert c.objective == pytest.approx(
        link_energy(gemm, chip.mapping, hw), rel=1e-12)


def test_mixed_factorization_beats_single_axis_on_square():
    """For words_A == words_B = w, (2,2,1) moves w/2 over ICI vs 0.75w
    for any single 4-way axis — the analytic win the joint solver must
    find (module docstring of dist.mesh_solve)."""
    hw = TEMPLATES["gemmini-like"]
    gemm = Gemm(64, 64, 64, "square")
    res = solve_sharded(gemm, hw, 4)
    c = res.certificate
    assert c.feasible
    cx, cy, cz = c.counts
    assert max(cx, cy, cz) < 4, c.counts       # mixed, not single-axis
    assert c.savings > 0.0, c.summary()


def test_collective_words_ring_model():
    gemm = Gemm(8, 16, 32, "g")
    w = collective_words(gemm, (2, 1, 1))
    name, words = w["x"]
    assert name == "all-gather(B)"
    # B shard words_B / (cy*cz) times ring factor (c-1)/c
    assert words == pytest.approx((16 * 32) * (1 / 2))
    w = collective_words(gemm, (1, 1, 4))
    name, words = w["z"]
    assert name == "reduce-scatter(P)"
    assert words == pytest.approx((8 * 16) * (3 / 4))
    assert collective_words(gemm, (1, 1, 1)) == {}


def test_independent_matches_dist_mapping_ranking():
    """The baseline's partition is the first divisible choice of the
    ICI-bytes ranking — pin the contract against plan_shard_axis."""
    hw = TEMPLATES["eyeriss-like"]
    gemm = Gemm(12, 4, 6, "ragged")
    n = 2
    res = solve_sharded(gemm, hw, n)
    c = res.certificate
    expect = None
    for choice in plan_shard_axis(gemm, n, dtype_bytes=1):
        i = "xyz".index(choice.axis)
        if gemm.dims[i] % n == 0:
            expect = tuple(n if j == i else 1 for j in range(3))
            break
    assert c.independent_counts == expect


def test_partition_specs_tp_dp_shapes():
    # pure-y partition == TP rules: B (K,N) sharded on "model", A replicated
    specs = partition_specs((1, 4, 1))
    assert specs == {"A": (None, None), "B": (None, "model"),
                     "P": (None, "model")}
    # pure-x partition == DP: A and P batch-sharded on "data"
    specs = partition_specs((2, 1, 1))
    assert specs == {"A": ("data", None), "B": (None, None),
                     "P": ("data", None)}
    specs = partition_specs((2, 2, 2))
    assert specs["A"] == ("data", "reduce")
    assert specs["B"] == ("reduce", "model")
    assert specs["P"] == ("data", "model")
    assert MeshSpec((2, 2, 2)).axis_names == ("data", "model", "reduce")


def test_enumerate_partitions_divisibility():
    gemm = Gemm(8, 3, 5, "odd")
    parts = enumerate_partitions(gemm, 4)
    assert parts == [(4, 1, 1)]        # 3 and 5 indivisible by 2 or 4
    assert enumerate_partitions(Gemm(3, 3, 3, "p"), 4) == []


def test_infeasible_partition_certificate():
    hw = TEMPLATES["eyeriss-like"]
    gemm = Gemm(3, 3, 3, "prime")
    res = solve_sharded(gemm, hw, 4)
    c = res.certificate
    assert not c.feasible and c.counts is None and res.mapping is None
    assert c.objective == math.inf and c.n_partitions == 0
    assert verify_sharded(c, hw, None)


def test_verify_sharded_rejects_tampering():
    hw = TEMPLATES["gemmini-like"]
    gemm = Gemm(16, 16, 16, "t")
    res = solve_sharded(gemm, hw, 2)
    c = res.certificate
    assert verify_sharded(c, hw, res.mapping)
    # claimed objective lowered below what re-derivation produces
    bad = dataclasses.replace(c, objective=c.objective * 0.5,
                              upper_bound=c.objective * 0.5,
                              lower_bound=c.objective * 0.5,
                              chip_pj=c.chip_pj * 0.5)
    assert not verify_sharded(bad, hw, res.mapping)
    # counts that don't multiply to n_chips
    bad = dataclasses.replace(c, counts=(1, 1, 1))
    assert not verify_sharded(bad, hw, res.mapping)
    # wrong hardware
    assert not verify_sharded(c, TEMPLATES["eyeriss-like"], res.mapping)
    # feasible cert without a mapping
    assert not verify_sharded(c, hw, None)


def test_objective_energy_only():
    hw = TEMPLATES["eyeriss-like"]
    with pytest.raises(ValueError, match="energy"):
        solve_sharded(Gemm(8, 8, 8, "g"), hw, 2, objective="edp")
    with pytest.raises(ValueError, match="n_chips"):
        solve_sharded(Gemm(8, 8, 8, "g"), hw, 0)


# ---------------------------------------------------------------------------
# sharded plan store
# ---------------------------------------------------------------------------

def test_sharded_certificate_json_roundtrip():
    hw = TEMPLATES["gemmini-like"]
    res = solve_sharded(Gemm(16, 32, 8, "rt"), hw, 4, dtype_bytes=2)
    c = res.certificate
    back = sharded_certificate_from_json(sharded_certificate_to_json(c))
    assert back == c
    assert verify_sharded(back, hw, res.mapping)


def test_sharded_store_roundtrip(tmp_path):
    hw = TEMPLATES["gemmini-like"]
    gemm = Gemm(16, 32, 8, "store")
    store = PlanStore(tmp_path)
    key = sharded_plan_key(gemm, hw, 4, dtype_bytes=2)
    assert store.get_sharded(key) is None
    assert not store.contains_sharded(key)

    res = cached_solve_sharded(gemm, hw, 4, dtype_bytes=2, store=store)
    assert store.contains_sharded(key)
    assert store.num_sharded() == 1
    assert store.stats()["sharded_entries"] == 1

    entry = store.get_sharded(key)
    assert entry.certificate == res.certificate
    assert entry.mapping == res.mapping
    assert entry.counts == res.certificate.counts
    assert entry.partition_specs == res.specs
    assert verify_sharded(entry.certificate, hw, entry.mapping)

    # cold store object re-reads from disk
    store2 = PlanStore(tmp_path)
    entry2 = store2.get_sharded(key)
    assert entry2.certificate == res.certificate
    assert entry2.mapping == res.mapping
    report = store2.fsck()
    assert report["corrupt"] == [] and report["ok"] == report["checked"]


def test_sharded_store_hit_skips_all_solves(tmp_path):
    hw = TEMPLATES["gemmini-like"]
    gemm = Gemm(16, 16, 16, "hit")
    store = PlanStore(tmp_path)
    miss = cached_solve_sharded(gemm, hw, 2, store=store)
    before = solver_stats()["calls"]
    hit = cached_solve_sharded(gemm, hw, 2, store=store)
    assert solver_stats()["calls"] == before          # zero solver calls
    assert hit.certificate == miss.certificate
    assert hit.mapping == miss.mapping


def test_sharded_miss_caches_sub_plans(tmp_path):
    """One sharded miss leaves each sub-GEMM's single-chip plan in the
    store: the single-chip dispatch path benefits from mesh planning."""
    hw = TEMPLATES["gemmini-like"]
    gemm = Gemm(16, 16, 16, "sub")
    store = PlanStore(tmp_path)
    cached_solve_sharded(gemm, hw, 2, store=store)
    assert len(store) > 0                             # single-chip section
    assert store.num_sharded() == 1


def test_cli_inspect_verify_sharded_section(tmp_path, capsys):
    from repro.planner.cli import main
    hw = TEMPLATES["gemmini-like"]
    store = PlanStore(tmp_path)
    cached_solve_sharded(Gemm(16, 32, 8, "cli"), hw, 4, dtype_bytes=2,
                         store=store)
    assert main(["inspect", "--store", str(tmp_path), "-v"]) == 0
    out = capsys.readouterr().out
    assert "1 sharded mesh plan" in out
    assert "chips=4" in out and "specs=" in out
    assert main(["verify", "--store", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "sharded" in out and "FAIL" not in out
    # re-store an entry whose certificate claims a too-good objective
    # (valid checksum, so it survives load and must fail verification)
    entry = next(iter(store.sharded_entries()))
    bad_cert = dataclasses.replace(entry.certificate,
                                   objective=entry.certificate.objective / 2,
                                   upper_bound=entry.certificate.objective / 2,
                                   lower_bound=entry.certificate.objective / 2)
    store.put_sharded(dataclasses.replace(entry, certificate=bad_cert))
    assert main(["verify", "--store", str(tmp_path)]) == 1
    assert "FAIL sharded" in capsys.readouterr().out


def test_sharded_key_distinguishes_chips_and_dtype():
    hw = TEMPLATES["gemmini-like"]
    g = Gemm(16, 16, 16, "k")
    k1 = sharded_plan_key(g, hw, 2)
    k2 = sharded_plan_key(g, hw, 4)
    k3 = sharded_plan_key(g, hw, 2, dtype_bytes=2)
    assert len({k1.digest, k2.digest, k3.digest}) == 3
