"""Multi-device integration via subprocess (8 fake CPU devices), so the
main test session keeps the default single device."""
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow    # ~30s subprocess with 8 fake devices

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_distributed_smoke():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "dist_smoke.py")],
        capture_output=True, text=True, timeout=880)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    for marker in ("LOSSES_OK", "RESHARD_OK", "GRADCOMP_OK", "ALL_OK"):
        assert marker in proc.stdout, proc.stdout
