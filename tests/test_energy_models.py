"""The three-way model validation (DESIGN.md §3):

  closed form  ==  no-reuse loop-nest reference      (always, by identity)
  full-reuse loop-nest reference == literal simulator (always, ground truth)
  closed form  ==  literal simulator                  (whenever the
        exactness predicate holds; conservative otherwise)
"""
import random

import pytest

from repro.core import (EYERISS_LIKE, Gemm, Mapping, analytical_counts,
                        analytical_energy, closed_form_is_exact,
                        reference_counts, simulate_counts)
from repro.core.geometry import AXES, canonical_walk, divisor_chains

GEMMS = [Gemm(4, 4, 4), Gemm(8, 4, 6), Gemm(12, 6, 8), Gemm(5, 7, 3),
         Gemm(16, 8, 4), Gemm(9, 6, 12)]


def _random_mapping(rng, gemm):
    chains = [rng.choice(divisor_chains(d)) for d in gemm.dims]
    return Mapping(
        L1=tuple(c[0] for c in chains), L2=tuple(c[1] for c in chains),
        L3=tuple(c[2] for c in chains),
        alpha01=rng.choice(AXES), alpha12=rng.choice(AXES),
        res1=tuple(rng.random() < 0.8 for _ in range(3)),
        res3=tuple(rng.random() < 0.8 for _ in range(3)))


@pytest.mark.parametrize("seed", range(4))
def test_three_way_consistency(seed):
    rng = random.Random(seed)
    n_exact = 0
    for gemm in GEMMS:
        for _ in range(40):
            m = _random_mapping(rng, gemm)
            cf = analytical_counts(gemm, m)
            ref_noreuse = reference_counts(gemm, m, full_reuse=False)
            ref_full = reference_counts(gemm, m, full_reuse=True)
            sim = simulate_counts(gemm, m)
            assert cf.isclose(ref_noreuse), (gemm, m)
            assert ref_full.isclose(sim), (gemm, m)
            if closed_form_is_exact(gemm, m):
                n_exact += 1
                assert cf.isclose(sim), (gemm, m)
    assert n_exact > 20  # the predicate fires often enough to be meaningful


def test_closed_form_is_conservative():
    """The closed form never undercounts total energy vs full reuse."""
    rng = random.Random(123)
    hw = EYERISS_LIKE
    for gemm in GEMMS:
        for _ in range(40):
            m = _random_mapping(rng, gemm)
            e_cf = analytical_counts(gemm, m).energy(hw)
            e_ref = reference_counts(gemm, m, full_reuse=True).energy(hw)
            assert e_cf >= e_ref * (1 - 1e-9), (gemm, m)


def test_canonical_walk_exact_on_oracle():
    """Folding a walking-axis alias never changes the true (oracle) cost."""
    rng = random.Random(7)
    for gemm in GEMMS:
        for _ in range(30):
            m = _random_mapping(rng, gemm)
            c = canonical_walk(gemm, m)
            assert simulate_counts(gemm, m).isclose(
                simulate_counts(gemm, c)), (gemm, m, c)


def test_breakdown_matches_counts():
    gemm = Gemm(8, 8, 8)
    m = Mapping((4, 8, 4), (2, 4, 2), (1, 2, 1), "y", "z")
    bd = analytical_energy(gemm, m, EYERISS_LIKE)
    # term view and counts view agree
    assert bd.total == pytest.approx(bd.counts.energy(EYERISS_LIKE),
                                     rel=1e-9)
    assert bd.volume == gemm.volume
    assert bd.normalized > 0


def test_three_way_consistency_on_chain_links():
    """The fused planner's chain-link mappings — producer with the
    N-tile pinned full + P SRAM-resident, consumer with the K-tile
    pinned full + A SRAM-resident — obey the same three-way model
    equality as free mappings (seeded twin of the hypothesis lane in
    test_property.py, so the invariant is exercised without hypothesis
    installed)."""
    from repro.core.fusion import mlp_chain
    rng = random.Random(11)
    checked = 0
    for m_rows, ff, d_model in [(4, 8, 6), (8, 6, 4), (6, 12, 2),
                                (2, 4, 9)]:
        chain = mlp_chain(m_rows, ff, d_model)
        for _ in range(25):
            bm = rng.choice(divisor_chains(chain.M))[0]
            if rng.random() < 0.5:     # producer under the chain pins
                gemm = chain.producer
                pin_l1 = (bm, chain.inter_width, None)
                forced = 2             # P resident
            else:                      # consumer under the chain pins
                gemm = chain.consumer
                pin_l1 = (bm, None, chain.inter_width)
                forced = 1             # A resident
            chains = []
            for d in range(3):
                opts = divisor_chains(gemm.dims[d])
                if pin_l1[d] is not None:
                    opts = tuple(c for c in opts if c[0] == pin_l1[d])
                chains.append(rng.choice(opts))
            res1 = tuple(True if d == forced else rng.random() < 0.7
                         for d in range(3))
            m = Mapping(
                L1=tuple(c[0] for c in chains),
                L2=tuple(c[1] for c in chains),
                L3=tuple(c[2] for c in chains),
                alpha01=rng.choice(AXES), alpha12=rng.choice(AXES),
                res1=res1,
                res3=tuple(rng.random() < 0.7 for _ in range(3)))
            cf = analytical_counts(gemm, m)
            assert cf.isclose(reference_counts(gemm, m,
                                               full_reuse=False)), (gemm, m)
            full = reference_counts(gemm, m, full_reuse=True)
            sim = simulate_counts(gemm, m)
            assert full.isclose(sim), (gemm, m)
            if closed_form_is_exact(gemm, m):
                assert cf.isclose(sim), (gemm, m)
                checked += 1
    assert checked > 15


def test_rho_boundary_cases():
    """alpha01 = z: partial sums leave SRAM exactly once per element."""
    gemm = Gemm(8, 8, 8)
    m = Mapping((4, 4, 4), (2, 2, 2), (1, 1, 1), "z", "z")
    counts = analytical_counts(gemm, m)
    sim = simulate_counts(gemm, m)
    # DRAM writes of P == Lx*Ly (once per element, never read back)
    assert counts.dram_write == pytest.approx(gemm.Lx * gemm.Ly)
    assert sim.dram_write == pytest.approx(gemm.Lx * gemm.Ly)
