"""Fault-tolerance machinery: straggler watchdog, NaN guard, schedule."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, host_batch
from repro.training import LoopConfig, optimizer as opt, run_training
from repro.training.loop import LoopState

pytestmark = pytest.mark.slow    # watchdog sleeps + serve loops, ~15s


@pytest.fixture()
def host_data(monkeypatch):
    from repro.training import loop as loop_mod
    monkeypatch.setattr(
        loop_mod, "global_arrays",
        lambda cfg, s, _sh: {k: jnp.asarray(v)
                             for k, v in host_batch(cfg, s).items()})
    return DataConfig(vocab=97, seq_len=8, global_batch=2, seed=0)


def test_straggler_watchdog_counts(host_data):
    calls = {"n": 0}

    def slow_step(params, opt_state, batch):
        calls["n"] += 1
        if calls["n"] == 6:
            time.sleep(0.6)          # inject a straggler step
        else:
            time.sleep(0.02)
        return params, opt_state, {"loss": jnp.float32(1.0)}

    _, _, state = run_training(
        slow_step, {}, {}, host_data, None,
        LoopConfig(total_steps=8, ckpt_every=100, log_every=100,
                   straggler_factor=3.0),
        None, log=lambda s: None)
    assert state.straggler_steps >= 1
    assert state.step == 8


def test_nan_guard_checkpoints_and_raises(host_data, tmp_path):
    def nan_step(params, opt_state, batch):
        return params, opt_state, {"loss": jnp.float32(float("nan"))}

    mgr = CheckpointManager(tmp_path, async_save=False)
    with pytest.raises(FloatingPointError):
        run_training(nan_step, {"w": jnp.ones(3)}, {}, host_data, None,
                     LoopConfig(total_steps=5), mgr, log=lambda s: None)
    # the abort path left a checkpoint for post-mortem restart
    assert mgr.latest_step() == 1


def test_lr_schedule_shape():
    cfg = opt.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(opt.schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= cfg.lr + 1e-9           # warmup rises
    assert abs(max(lrs) - cfg.lr) < 1e-4 * cfg.lr      # peaks at lr
    assert lrs[-1] >= cfg.lr * cfg.min_lr_frac * 0.99  # floor respected
    assert lrs[-1] < lrs[50]                           # cosine decays


def test_adamw_decays_matrices_not_vectors():
    cfg = opt.AdamWConfig(lr=1e-2, weight_decay=0.5, warmup_steps=1,
                          total_steps=10)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    grads = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    state = opt.init_state(params)
    new_params, _, _ = opt.apply_updates(params, grads, state, cfg)
    assert float(jnp.max(new_params["w"])) < 1.0   # decayed
    assert float(jnp.max(new_params["b"])) == 1.0  # exempt


def test_serving_with_frontends():
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import Engine, ServeConfig
    for arch, key_name in (("llava-next-34b", "patches"),
                           ("seamless-m4t-medium", "frames")):
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        F = cfg.frontend_len
        eng = Engine(model, params, ServeConfig(max_new_tokens=4,
                                                cache_len=F + 32))
        prompts = np.ones((2, 6), np.int32)
        extra = {key_name: jnp.zeros((2, F, cfg.d_model))}
        out = eng.generate(prompts, extra_batch=extra)
        assert out.shape == (2, 4)
        assert (out >= 0).all() and (out < cfg.vocab).all()
