"""Fusion-aware chain planning: solve_chain exactness, certificate
claims, constrained-solve engine identity, the fused-plan store, and the
solve_many single-flight dedup audit."""
import numpy as np
import pytest

from repro.core import Gemm, TEMPLATES
from repro.core.fusion import (GemmChain, compatible_residency,
                               dram_roundtrip_credit, link_energy,
                               mlp_chain, solve_chain)
from repro.core.hardware import AcceleratorSpec, Ert
from repro.core.solver import (SolveRequest, reset_solver_stats, solve,
                               solve_many, solver_stats)

ERT = Ert(dram_read=200.0, dram_write=200.0, sram_read=6.0, sram_write=6.5,
          rf_read=1.0, rf_write=1.1, macc=2.0, sram_leak=0.1,
          rf_leak=0.001)


def tiny_hw(npe, sram, rf, **kw):
    return AcceleratorSpec(name=f"tiny{npe}", sram_words=sram, rf_words=rf,
                           num_pe=npe, ert=ERT, **kw)


# ---------------------------------------------------------------------------
# GemmChain structure
# ---------------------------------------------------------------------------

def test_chain_validation():
    GemmChain(Gemm(8, 16, 4), Gemm(8, 4, 16))            # valid tie
    with pytest.raises(ValueError):
        GemmChain(Gemm(8, 16, 4), Gemm(4, 4, 16))        # M mismatch
    with pytest.raises(ValueError):
        GemmChain(Gemm(8, 16, 4), Gemm(8, 4, 8))         # N1 != K2
    with pytest.raises(ValueError):
        GemmChain(Gemm(8, 16, 4), Gemm(8, 4, 16), producer_count=0)
    with pytest.raises(ValueError):
        GemmChain(Gemm(8, 16, 4), Gemm(8, 4, 16), elementwise="nope")


def test_mlp_chain_shape():
    c = mlp_chain(128, 512, 256)
    assert c.producer.dims == (128, 512, 256)
    assert c.consumer.dims == (128, 256, 512)
    assert c.producer_count == 2
    assert c.inter_words == 128 * 512
    assert c.total_volume == 2 * 128 * 512 * 256 + 128 * 256 * 512


# ---------------------------------------------------------------------------
# solve_chain: certificate claims
# ---------------------------------------------------------------------------

def test_chain_zero_gap_and_leq_sum():
    chain = mlp_chain(64, 48, 32)
    hw = tiny_hw(16, 8192, 32)
    res = solve_chain(chain, hw)
    c = res.certificate
    assert c.feasible and c.gap == 0.0
    # the headline claim: chain optimum <= sum of independent optima
    assert c.objective <= c.unfused_objective * (1 + 1e-12)
    # the unfused bound really is the sum of per-GEMM optima
    r1 = solve(chain.producer, hw)
    r2 = solve(chain.consumer, hw)
    expect = (2 * link_energy(chain.producer, r1.mapping, hw)
              + link_energy(chain.consumer, r2.mapping, hw))
    assert c.unfused_objective == pytest.approx(expect, rel=1e-12)
    if c.fused:
        assert c.objective < c.unfused_objective
        assert compatible_residency(chain, res.producer_mapping,
                                    res.consumer_mapping, hw)
        assert res.producer_mapping.L1[0] == c.bm
        assert res.consumer_mapping.L1[0] == c.bm
        assert res.producer_mapping.L1[1] == chain.inter_width
        assert res.consumer_mapping.L1[2] == chain.inter_width


def test_chain_fused_wins_when_strips_fit():
    # generous SRAM: the intermediate round-trip credit must be claimed
    chain = mlp_chain(64, 48, 32)
    hw = tiny_hw(16, 1 << 16, 64)
    c = solve_chain(chain, hw).certificate
    assert c.fused
    assert c.credit == dram_roundtrip_credit(chain, hw)
    assert c.objective == pytest.approx(
        c.unfused_objective - c.credit, rel=0.5)  # same order as credit


def test_chain_falls_back_unfused_when_residency_infeasible():
    # SRAM too small for even a bm=1 strip pair (2 * 1 * 48 words > 64)
    chain = mlp_chain(64, 48, 32)
    hw = tiny_hw(4, 64, 8)
    res = solve_chain(chain, hw)
    c = res.certificate
    assert c.feasible and not c.fused
    assert c.objective == c.unfused_objective
    assert c.gap == 0.0
    # unfused mappings are the independent optima
    assert res.producer_mapping == solve(chain.producer, hw).mapping


def test_chain_infeasible_instance():
    chain = mlp_chain(8, 8, 8)
    hw = tiny_hw(4, 2, 1, allow_bypass=False)   # nothing fits anywhere
    c = solve_chain(chain, hw).certificate
    assert not c.feasible
    assert c.objective == float("inf")


def test_chain_rejects_edp_objective():
    with pytest.raises(ValueError):
        solve_chain(mlp_chain(8, 8, 8), tiny_hw(4, 512, 8),
                    objective="edp")


def test_chain_single_producer():
    chain = GemmChain(Gemm(32, 24, 16), Gemm(32, 16, 24),
                      producer_count=1, elementwise="identity")
    hw = tiny_hw(8, 4096, 32)
    c = solve_chain(chain, hw).certificate
    assert c.feasible and c.gap == 0.0
    assert c.objective <= c.unfused_objective * (1 + 1e-12)
    assert c.credit == dram_roundtrip_credit(chain, hw)


def test_chain_engines_identical():
    """The constrained per-link solves inherit the engines' bit-identity:
    the whole chain result must match across engines."""
    chain = mlp_chain(48, 36, 24)
    hw = tiny_hw(8, 4096, 24)
    a = solve_chain(chain, hw, engine="reference")
    b = solve_chain(chain, hw, engine="vectorized")
    assert a.certificate.objective == b.certificate.objective
    assert a.certificate.fused == b.certificate.fused
    assert a.certificate.bm == b.certificate.bm
    assert a.producer_mapping == b.producer_mapping
    assert a.consumer_mapping == b.consumer_mapping


def test_paper_mlp_chains_fast_subset():
    """Acceptance fast lane: chain <= sum on one MLP chain per edge
    template (the slow lane sweeps every paper case)."""
    from repro.core.workloads import QWEN3_0_6B, prefill_chains
    rows = prefill_chains(QWEN3_0_6B, 1024)
    assert rows and rows[0][0] == "mlp_chain"
    chain = rows[0][1]
    for hw_name in ("eyeriss-like", "gemmini-like"):
        c = solve_chain(chain, TEMPLATES[hw_name]).certificate
        assert c.feasible and c.gap == 0.0
        assert c.objective <= c.unfused_objective * (1 + 1e-12)


@pytest.mark.slow
def test_paper_mlp_chains_all_cases():
    """Acceptance: zero-gap and fused <= sum on EVERY paper_cases() MLP
    chain (24 model/seq/hw combinations)."""
    from repro.core.workloads import paper_cases, prefill_chains
    for name, spec, seq, hw_name in paper_cases():
        chain = prefill_chains(spec, seq)[0][1]
        c = solve_chain(chain, TEMPLATES[hw_name]).certificate
        assert c.feasible, name
        assert c.gap == 0.0, name
        assert c.objective <= c.unfused_objective * (1 + 1e-12), name


# ---------------------------------------------------------------------------
# workload chain extraction
# ---------------------------------------------------------------------------

def test_workload_chain_extraction():
    from repro.core.workloads import (LLAMA32_1B, arch_decode_chains,
                                      decode_chains, prefill_chains)
    rows = prefill_chains(LLAMA32_1B, 2048)
    (_, chain, w), = rows
    assert chain.producer.dims == (2048, 8192, 2048)
    assert chain.consumer.dims == (2048, 2048, 8192)
    assert w == LLAMA32_1B.layers
    rows = decode_chains(LLAMA32_1B, 16, 4096)
    (_, chain, _), = rows
    assert chain.M == 16
    rows = arch_decode_chains("llama3-8b", batch=8)
    (_, chain, _), = rows
    assert chain.M == 8 and chain.producer_count == 2
    # recurrent families contribute no fusable MLP chains, and MoE
    # expert GEMMs never route through the fused op (moe_apply), so
    # dispatch-matching extraction must skip them too
    assert arch_decode_chains("rwkv6-7b", batch=8) == []
    assert arch_decode_chains("deepseek-moe-16b", batch=8) == []


# ---------------------------------------------------------------------------
# fused-plan store
# ---------------------------------------------------------------------------

def test_fused_store_roundtrip_and_readthrough(tmp_path):
    from repro.planner.batch import cached_solve_chain
    from repro.planner.store import (FusedPlanEntry, PlanStore,
                                     chain_plan_key)
    chain = mlp_chain(64, 48, 32)
    hw = tiny_hw(16, 8192, 32)
    store = PlanStore(tmp_path)
    reset_solver_stats()
    res = cached_solve_chain(chain, hw, store=store)
    n_first = solver_stats()["calls"]
    assert n_first > 0
    assert store.num_fused() == 1
    # warm read-through: zero solves, identical certificate
    reset_solver_stats()
    res2 = cached_solve_chain(chain, hw, store=store)
    assert solver_stats()["calls"] == 0
    assert res2.certificate.objective == res.certificate.objective
    assert res2.producer_mapping == res.producer_mapping
    # cold process (fresh store object): disk round-trip bit-exact
    reread = PlanStore(tmp_path).get_fused(chain_plan_key(chain, hw))
    assert isinstance(reread, FusedPlanEntry)
    assert reread.certificate.objective == res.certificate.objective
    assert reread.certificate.fused == res.certificate.fused
    assert reread.producer_mapping == res.producer_mapping
    assert reread.consumer_mapping == res.consumer_mapping
    # fused entries are invisible to single-GEMM iteration
    assert list(store.entries()) == []
    assert len(store) == 0


def test_chain_key_distinguishes_chains():
    from repro.planner.store import chain_plan_key
    hw = tiny_hw(16, 8192, 32)
    k1 = chain_plan_key(mlp_chain(64, 48, 32), hw)
    k2 = chain_plan_key(mlp_chain(64, 48, 16), hw)
    k3 = chain_plan_key(GemmChain(Gemm(64, 48, 32), Gemm(64, 32, 48),
                                  producer_count=1), hw)
    assert len({k1.digest, k2.digest, k3.digest}) == 3


def test_tpu_fused_plan_prewarm(tmp_path):
    from repro.core import tpu_mapping
    from repro.planner.batch import prewarm_fused_plans
    from repro.planner.store import PlanStore
    store = PlanStore(tmp_path)
    shapes = [(256, 512, 256, 256)]
    try:
        n = prewarm_fused_plans(shapes, store, dtype_bytes=4)
        assert n == 1 and store.num_fused() == 1
        # a fresh process (cache cleared) resolves from the store with
        # zero solver invocations
        tpu_mapping.set_plan_store(None)
        tpu_mapping.set_plan_store(PlanStore(tmp_path))
        reset_solver_stats()
        plan = tpu_mapping.plan_fused_mlp(256, 512, 256, 256,
                                          dtype_bytes=4)
        assert solver_stats()["calls"] == 0
        assert plan.fused and plan.bm > 0
    finally:
        tpu_mapping.set_plan_store(None)


# ---------------------------------------------------------------------------
# satellite: solve_many duplicate-request audit (single-flight)
# ---------------------------------------------------------------------------

def test_solve_many_single_flights_identical_requests():
    hw = tiny_hw(8, 512, 16)
    req = SolveRequest(gemm=Gemm(8, 8, 8), hw=hw)
    reset_solver_stats()
    results = solve_many([req] * 7)
    assert solver_stats()["calls"] == 1
    assert len(results) == 7
    assert all(r is results[0] for r in results)
    # a distinct request still solves separately...
    reset_solver_stats()
    other = SolveRequest(gemm=Gemm(8, 8, 4), hw=hw)
    results = solve_many([req, other, req, other])
    assert solver_stats()["calls"] == 2
    # ...and name-only differences share one flight (names are metadata)
    reset_solver_stats()
    named = SolveRequest(gemm=Gemm(8, 8, 8, "alias"), hw=hw)
    solve_many([req, named])
    assert solver_stats()["calls"] == 1
