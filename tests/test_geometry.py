"""Unit tests: compute-grid geometry, divisor lattice, mapping encoding."""
import pytest

from repro.core.geometry import (AXES, Gemm, Mapping, canonical_walk,
                                 divisor_chains, divisors,
                                 enumerate_mappings, mapping_space_size,
                                 pad_to_divisor_rich)


def test_divisors():
    assert divisors(12) == (1, 2, 3, 4, 6, 12)
    assert divisors(1) == (1,)
    assert divisors(17) == (1, 17)


def test_divisor_chains_structure():
    for n in (8, 12, 60):
        chains = divisor_chains(n)
        for l1, l2, l3 in chains:
            assert n % l1 == 0 and l1 % l2 == 0 and l2 % l3 == 0
        assert len(set(chains)) == len(chains)


def test_divisor_chain_count_power_of_two():
    # chains over 2^a: choose 0 <= i <= j <= k <= a -> C(a+3, 3)
    import math
    a = 5
    expect = math.comb(a + 3, 3)
    assert len(divisor_chains(2 ** a)) == expect


def test_gemm_projections():
    g = Gemm(3, 5, 7)
    assert g.volume == 105
    assert g.words_A == 21 and g.words_B == 35 and g.words_P == 15


def test_mapping_validation():
    g = Gemm(8, 8, 8)
    m = Mapping((4, 4, 4), (2, 2, 2), (1, 1, 1), "x", "y")
    m.validate(g)
    bad = Mapping((3, 4, 4), (2, 2, 2), (1, 1, 1), "x", "y")
    with pytest.raises(ValueError):
        bad.validate(g)
    assert m.spatial == (2, 2, 2)
    assert m.num_pe_used == 8


def test_mapping_space_size_counts_enumeration():
    g = Gemm(4, 2, 2)
    n = sum(1 for _ in enumerate_mappings(g))
    assert n == mapping_space_size(g)


def test_canonical_walk_folds_unit_trips():
    g = Gemm(8, 8, 8)
    # L1 = dims on x => stage 0-1 trip on x is 1: walking x is an alias
    m = Mapping((8, 4, 4), (2, 2, 2), (1, 1, 1), "x", "z")
    c = canonical_walk(g, m)
    assert c.alpha01 != "x" or all(
        g.dims[i] // m.L1[i] == 1 for i in range(3))
    # non-degenerate mapping unchanged
    m2 = Mapping((4, 4, 4), (2, 2, 2), (1, 1, 1), "y", "z")
    assert canonical_walk(g, m2) is m2


def test_pad_to_divisor_rich():
    assert pad_to_divisor_rich(96) == 96  # already rich
    p = pad_to_divisor_rich(97)
    assert p >= 97 and len(divisors(p)) > len(divisors(97))
