"""Pallas kernel validation: shape/dtype sweep vs the pure-jnp oracles in
interpret mode (assignment requirement), plus the chunked-scan kernels'
algorithmic cores vs their sequential references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tpu_mapping import MXU, plan_gemm_tiling, tpu_spec
from repro.kernels.ops import gemm
from repro.kernels.ref import matmul_ref, ssd_ref, wkv6_ref

SHAPES = [(128, 128, 128), (256, 512, 128), (300, 200, 100),
          (512, 384, 1024), (1024, 256, 2048), (64, 4096, 512)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_goma_gemm_vs_ref(shape, dtype):
    M, N, K = shape
    a = (jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
         * 0.1).astype(dtype)
    b = (jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
         * 0.1).astype(dtype)
    out = gemm(a, b, interpret=True)
    ref = matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_plan_respects_hardware_constraints():
    hw = tpu_spec(2)
    for (M, N, K) in [(4096, 4096, 4096), (8192, 1024, 8192),
                      (128, 256000, 4608), (300, 200, 100)]:
        plan = plan_gemm_tiling(M, N, K, dtype_bytes=2)
        bm, bn, bk = plan.block
        pm, pn, pk = plan.padded
        assert pm % MXU == 0 and pn % MXU == 0
        assert pm % bm == 0 and pn % bn == 0 and pk % bk == 0
        # VMEM capacity (the GOMA SRAM constraint, words = bytes/2)
        assert bm * bk + bk * bn + bm * bn <= hw.sram_words
        # MXU alignment of the VMEM tile
        assert bm % MXU == 0 and bn % MXU == 0
        # realizability: z-walk or full reduction per block
        assert plan.walk == "z" or bk == pk
        # grid order puts the walking axis innermost
        assert plan.grid_order[-1] == {"x": "m", "y": "n",
                                       "z": "k"}[plan.walk]


def test_plan_grid_covers_problem():
    plan = plan_gemm_tiling(1000, 3000, 500, dtype_bytes=4)
    sizes = dict(zip(plan.grid_order, plan.grid))
    pm, pn, pk = plan.padded
    bm, bn, bk = plan.block
    assert sizes["m"] * bm == pm
    assert sizes["n"] * bn == pn
    assert sizes["k"] * bk == pk


def test_wkv6_chunked_vs_sequential():
    from repro.models.rwkv import wkv_chunked
    B, S, H, P = 2, 24, 3, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, P)) * 0.5
               for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, P)) - 2.0)
    u = jax.random.normal(ks[4], (H, P)) * 0.3
    y_c, s_c = wkv_chunked(r, k, v, logw, u, chunk=8)
    y_r = wkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunked_vs_sequential():
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 2, 24, 3, 8, 4
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.2
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    D = jnp.ones((H,)) * 0.1
    y_c, s_c = ssd_chunked(xh, dt, a_log, Bm, Cm, D, chunk=8)
    y_r = ssd_ref(xh, dt, a_log, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)


def test_gemm_plan_deterministic_and_cached():
    p1 = plan_gemm_tiling(512, 512, 512, dtype_bytes=2)
    p2 = plan_gemm_tiling(512, 512, 512, dtype_bytes=2)
    assert p1 is p2  # lru_cache


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_ssd_pallas_vs_ref(chunk):
    from repro.kernels.mamba2_ssd import ssd_pallas
    B, S, H, P, N = 2, 128, 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.2
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y, st = ssd_pallas(xh, dt, a_log, Bm, Cm, chunk=chunk, interpret=True)
    from repro.models.ssm import ssd_chunked
    _, st_ref = ssd_chunked(xh, dt, a_log, Bm, Cm, jnp.zeros((H,)),
                            chunk=chunk)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-3, atol=1e-3)
    ref = ssd_ref(xh, dt, a_log, Bm, Cm, jnp.zeros((H,)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk,dtype", [(32, jnp.float32),
                                         (64, jnp.float32),
                                         (32, jnp.bfloat16)])
def test_wkv6_pallas_vs_ref(chunk, dtype):
    from repro.kernels.wkv6 import wkv6_pallas
    B, S, H, P = 2, 128, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r, k, v = ((jax.random.normal(ks[i], (B, S, H, P)) * 0.5).astype(dtype)
               for i in range(3))
    logw = (-jnp.exp(jax.random.normal(ks[3], (B, S, H, P)) - 2.0)
            ).astype(dtype)
    u = jax.random.normal(ks[4], (H, P)) * 0.3
    y, st = wkv6_pallas(r, k, v, logw, u, chunk=chunk, interpret=True)
    # final state must match the chunked JAX implementation's
    from repro.models.rwkv import wkv_chunked
    _, st_ref = wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32),
                            logw.astype(jnp.float32), u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=2e-3, atol=2e-3)
    ref = wkv6_ref(r.astype(jnp.float32), k.astype(jnp.float32),
                   v.astype(jnp.float32), logw.astype(jnp.float32), u)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref), rtol=tol, atol=tol)
