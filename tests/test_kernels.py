"""Pallas kernel validation: shape/dtype sweep vs the pure-jnp oracles in
interpret mode (assignment requirement), plus the chunked-scan kernels'
algorithmic cores vs their sequential references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tpu_mapping import (MXU, FusedTilePlan, TpuTilePlan,
                                    plan_fused_mlp, plan_gemm_tiling,
                                    tpu_spec)
from repro.kernels.goma_gemm import goma_matmul
from repro.kernels.ops import fused_mlp, fused_mlp_composition, gemm
from repro.kernels.ref import matmul_ref, ssd_ref, wkv6_ref

SHAPES = [(128, 128, 128), (256, 512, 128), (300, 200, 100),
          (512, 384, 1024), (1024, 256, 2048), (64, 4096, 512)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.1).astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_goma_gemm_vs_ref(shape, dtype):
    M, N, K = shape
    a = (jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
         * 0.1).astype(dtype)
    b = (jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
         * 0.1).astype(dtype)
    out = gemm(a, b, interpret=True)
    ref = matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# --- kernel numerics matrix: goma_matmul + fused kernel -------------------
# odd / non-divisor-rich shapes alongside MXU-friendly ones; the fused
# matrix also pins the nk == 1 fast path and the multi-k scratch path
# via handcrafted plans (deterministic, VMEM-size-independent).

MATRIX_SHAPES = [(128, 128, 128), (300, 200, 100), (129, 257, 65),
                 (100, 50, 1), (256, 384, 512)]


@pytest.mark.parametrize("shape", MATRIX_SHAPES,
                         ids=[f"{m}x{n}x{k}" for m, n, k in MATRIX_SHAPES])
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_goma_gemm_matrix(shape, dtype):
    M, N, K = shape
    a = _rand(jax.random.PRNGKey(0), (M, K), dtype)
    b = _rand(jax.random.PRNGKey(1), (K, N), dtype)
    out = gemm(a, b, interpret=True)
    ref = matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", MATRIX_SHAPES,
                         ids=[f"{m}x{n}x{k}" for m, n, k in MATRIX_SHAPES])
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_fused_mlp_matrix(shape, dtype):
    """Fused kernel vs jnp reference AND bit-identical to the unfused
    two-goma_matmul composition under the plan's compatibility tiles."""
    M, FF, K = shape
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    a = _rand(ks[0], (M, K), dtype)
    wg = _rand(ks[1], (K, FF), dtype)
    wu = _rand(ks[2], (K, FF), dtype)
    wd = _rand(ks[3], (FF, K), dtype)
    out = fused_mlp(a, wg, wu, wd, interpret=True)
    ref = fused_mlp(a, wg, wu, wd, force_xla=True)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)
    plan = plan_fused_mlp(M, FF, K,
                          dtype_bytes=jnp.dtype(dtype).itemsize)
    if plan.fused:
        comp = fused_mlp_composition(a, wg, wu, wd, plan, interpret=True)
        assert np.array_equal(np.asarray(out), np.asarray(comp)), (
            shape, dtype)


def _manual_fused_plan(M, FF, K, bm, bk):
    return FusedTilePlan(M=M, FF=FF, K=K, N2=K, padded=(M, FF, K, K),
                         fused=True, bm=bm, bk=bk, objective=0.0,
                         unfused_objective=0.0, solve_time_s=0.0)


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("bm,bk,label", [
    (128, 128, "single_k"),        # nk == 1 fast path (no scratch)
    (128, 64, "multi_k"),          # VMEM scratch accumulation path
    (64, 32, "multi_m_multi_k"),   # both grid dims > 1
])
def test_fused_kernel_grid_paths(dtype, bm, bk, label):
    """The fused kernel's nk==1 fast path and scratch-accumulation path
    are bit-identical to the composition built from the same tiles."""
    M, FF, K = 128, 256, 128
    plan = _manual_fused_plan(M, FF, K, bm, bk)
    nm, nk = plan.grid
    assert (nk == 1) == (label == "single_k")
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    a = _rand(ks[0], (M, K), dtype)
    wg = _rand(ks[1], (K, FF), dtype)
    wu = _rand(ks[2], (K, FF), dtype)
    wd = _rand(ks[3], (FF, K), dtype)
    out = fused_mlp(a, wg, wu, wd, plan=plan, interpret=True)
    comp = fused_mlp_composition(a, wg, wu, wd, plan, interpret=True)
    assert np.array_equal(np.asarray(out), np.asarray(comp)), label
    ref = fused_mlp(a, wg, wu, wd, force_xla=True)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bk,expect_single", [(128, True), (64, False)])
def test_goma_gemm_nk1_fast_path(bk, expect_single):
    """goma_matmul's nk==1 path (direct block write, no accumulator
    scratch) computes the same result as the accumulated path."""
    M = N = K = 128
    plan = TpuTilePlan(M=M, N=N, K=K, padded=(M, N, K),
                       block=(128, 128, bk), grid_order=("m", "n", "k"),
                       walk="z", objective=0.0, solve_time_s=0.0)
    nk = K // bk
    assert (nk == 1) == expect_single
    a = _rand(jax.random.PRNGKey(4), (M, K), jnp.float32)
    b = _rand(jax.random.PRNGKey(5), (K, N), jnp.float32)
    out = goma_matmul(a, b, plan, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("activation", ["silu_mul", "gelu_mul",
                                        "sqrelu_mul"])
def test_fused_mlp_activations(activation):
    M, FF, K = 128, 128, 128
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    a = _rand(ks[0], (M, K), jnp.float32)
    wg = _rand(ks[1], (K, FF), jnp.float32)
    wu = _rand(ks[2], (K, FF), jnp.float32)
    wd = _rand(ks[3], (FF, K), jnp.float32)
    out = fused_mlp(a, wg, wu, wd, activation=activation, interpret=True)
    ref = fused_mlp(a, wg, wu, wd, activation=activation, force_xla=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_plan_respects_hardware_constraints():
    hw = tpu_spec(2)
    for (M, N, K) in [(4096, 4096, 4096), (8192, 1024, 8192),
                      (128, 256000, 4608), (300, 200, 100)]:
        plan = plan_gemm_tiling(M, N, K, dtype_bytes=2)
        bm, bn, bk = plan.block
        pm, pn, pk = plan.padded
        assert pm % MXU == 0 and pn % MXU == 0
        assert pm % bm == 0 and pn % bn == 0 and pk % bk == 0
        # VMEM capacity (the GOMA SRAM constraint, words = bytes/2)
        assert bm * bk + bk * bn + bm * bn <= hw.sram_words
        # MXU alignment of the VMEM tile
        assert bm % MXU == 0 and bn % MXU == 0
        # realizability: z-walk or full reduction per block
        assert plan.walk == "z" or bk == pk
        # grid order puts the walking axis innermost
        assert plan.grid_order[-1] == {"x": "m", "y": "n",
                                       "z": "k"}[plan.walk]


def test_plan_grid_covers_problem():
    plan = plan_gemm_tiling(1000, 3000, 500, dtype_bytes=4)
    sizes = dict(zip(plan.grid_order, plan.grid))
    pm, pn, pk = plan.padded
    bm, bn, bk = plan.block
    assert sizes["m"] * bm == pm
    assert sizes["n"] * bn == pn
    assert sizes["k"] * bk == pk


def test_wkv6_chunked_vs_sequential():
    from repro.models.rwkv import wkv_chunked
    B, S, H, P = 2, 24, 3, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, P)) * 0.5
               for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, P)) - 2.0)
    u = jax.random.normal(ks[4], (H, P)) * 0.3
    y_c, s_c = wkv_chunked(r, k, v, logw, u, chunk=8)
    y_r = wkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunked_vs_sequential():
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 2, 24, 3, 8, 4
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.2
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    D = jnp.ones((H,)) * 0.1
    y_c, s_c = ssd_chunked(xh, dt, a_log, Bm, Cm, D, chunk=8)
    y_r = ssd_ref(xh, dt, a_log, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)


def test_gemm_plan_deterministic_and_cached():
    p1 = plan_gemm_tiling(512, 512, 512, dtype_bytes=2)
    p2 = plan_gemm_tiling(512, 512, 512, dtype_bytes=2)
    assert p1 is p2  # lru_cache


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_ssd_pallas_vs_ref(chunk):
    from repro.kernels.mamba2_ssd import ssd_pallas
    B, S, H, P, N = 2, 128, 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.2
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    y, st = ssd_pallas(xh, dt, a_log, Bm, Cm, chunk=chunk, interpret=True)
    from repro.models.ssm import ssd_chunked
    _, st_ref = ssd_chunked(xh, dt, a_log, Bm, Cm, jnp.zeros((H,)),
                            chunk=chunk)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-3, atol=1e-3)
    ref = ssd_ref(xh, dt, a_log, Bm, Cm, jnp.zeros((H,)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk,dtype", [(32, jnp.float32),
                                         (64, jnp.float32),
                                         (32, jnp.bfloat16)])
def test_wkv6_pallas_vs_ref(chunk, dtype):
    from repro.kernels.wkv6 import wkv6_pallas
    B, S, H, P = 2, 128, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r, k, v = ((jax.random.normal(ks[i], (B, S, H, P)) * 0.5).astype(dtype)
               for i in range(3))
    logw = (-jnp.exp(jax.random.normal(ks[3], (B, S, H, P)) - 2.0)
            ).astype(dtype)
    u = jax.random.normal(ks[4], (H, P)) * 0.3
    y, st = wkv6_pallas(r, k, v, logw, u, chunk=chunk, interpret=True)
    # final state must match the chunked JAX implementation's
    from repro.models.rwkv import wkv_chunked
    _, st_ref = wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32),
                            logw.astype(jnp.float32), u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=2e-3, atol=2e-3)
    ref = wkv6_ref(r.astype(jnp.float32), k.astype(jnp.float32),
                   v.astype(jnp.float32), logw.astype(jnp.float32), u)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref), rtol=tol, atol=tol)
