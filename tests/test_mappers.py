"""Mapper suite behaviour: feasibility everywhere + GOMA dominance."""
import pytest

from repro.core import Gemm, TEMPLATES
from repro.core.mappers import ALL_MAPPERS

PAIRS = [
    ("eyeriss-like", Gemm(1024, 2048, 2048)),
    ("gemmini-like", Gemm(1024, 8192, 2048)),
    ("a100-like", Gemm(1, 128256, 8192)),       # lm_head matrix-vector
    ("tpuv1-like", Gemm(2048, 2048, 128)),       # attn-score-like
]


@pytest.mark.parametrize("hw_name,gemm", PAIRS,
                         ids=[f"{h}-{g.dims}" for h, g in PAIRS])
def test_all_mappers_feasible_and_goma_dominates(hw_name, gemm):
    hw = TEMPLATES[hw_name]
    results = {}
    for name, cls in ALL_MAPPERS.items():
        r = cls(seed=1).map(gemm, hw)
        assert r.mapping is not None, (name, hw_name, gemm)
        assert r.report.edp > 0
        results[name] = r
    best = results["goma"].edp
    for name, r in results.items():
        assert r.edp >= best * (1 - 1e-9), \
            f"{name} beat GOMA: {r.edp} < {best}"


def test_goma_certificate_attached():
    hw = TEMPLATES["eyeriss-like"]
    r = ALL_MAPPERS["goma"](seed=0).map(Gemm(256, 512, 128), hw)
    cert = r.extra["certificate"]
    assert cert.feasible and cert.gap == 0.0
    assert "certificate" in cert.summary()


def test_goma_eq_matches_paper_equivalence():
    """§V-A4: under eq. 29 equality, min-E == min-EDP — the relaxed EDP
    solver can only do as well or better."""
    hw = TEMPLATES["a100-like"]
    gemm = Gemm(2048, 25600, 5120)
    r_edp = ALL_MAPPERS["goma"](seed=0).map(gemm, hw)
    r_eq = ALL_MAPPERS["goma-eq"](seed=0).map(gemm, hw)
    assert r_edp.edp <= r_eq.edp * (1 + 1e-9)


def test_mappers_deterministic():
    hw = TEMPLATES["eyeriss-like"]
    gemm = Gemm(512, 512, 512)
    for name in ("goma", "cosa", "factorflow", "loma"):
        r1 = ALL_MAPPERS[name](seed=3).map(gemm, hw)
        r2 = ALL_MAPPERS[name](seed=3).map(gemm, hw)
        assert r1.mapping == r2.mapping, name
