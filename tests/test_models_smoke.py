"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of the same family runs one forward/train step on CPU with correct output
shapes and no NaNs; plus prefill+decode teacher-forcing consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model

pytestmark = pytest.mark.slow    # 10-arch train/decode sweep, ~90s

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=8, with_labels=True, key=jax.random.PRNGKey(3)):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if with_labels:
        batch["labels"] = jnp.roll(toks, -1, axis=1)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init_params(KEY)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch, remat=True))(params)
    assert jnp.isfinite(loss), arch
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode_matches_full_forward(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init_params(KEY)
    B, S = 2, 8
    F = cfg.frontend_len if cfg.family in ("vlm",) else 0
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S + 1), 0,
                              cfg.vocab)
    batch = _batch(cfg, B=B, S=S, with_labels=False)
    batch["tokens"] = toks[:, :S]
    logits_pre, cache = model.prefill(params, batch, max_len=F + S + 4)
    assert logits_pre.shape == (B, 1, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits_pre))
    logits_dec, _ = model.decode_step(
        params, cache, toks[:, S:S + 1], jnp.asarray(F + S, jnp.int32))
    batch2 = dict(batch)
    batch2["tokens"] = toks
    logits_full, _ = model.prefill(params, batch2, max_len=F + S + 8)
    err = float(jnp.max(jnp.abs(logits_dec[:, -1] - logits_full[:, -1])))
    assert err < 2e-4, (arch, err)


def test_gemma2_softcaps_and_alternation_active():
    cfg = get_config("gemma2-27b", smoke=True)
    assert cfg.alt_local_global and cfg.window and cfg.logit_softcap
    model = build_model(cfg)
    params = model.init_params(KEY)
    batch = _batch(cfg, S=16)
    loss = model.loss(params, batch, remat=False)
    assert jnp.isfinite(loss)
    # logits obey the softcap bound
    logits, _ = model.prefill(params, {"tokens": batch["tokens"]},
                              max_len=20)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.logit_softcap + 1e-3


def test_moe_routing_statistics():
    cfg = get_config("deepseek-moe-16b", smoke=True)
    from repro.models import moe as MOE
    p = MOE.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    y, aux = MOE.moe_apply(p, cfg, x)
    assert y.shape == x.shape
    assert jnp.isfinite(aux) and aux >= 1.0 - 1e-3  # >= 1 at balance


def test_full_configs_match_assignment():
    """Spot-check the exact assigned dimensions."""
    a = ARCHS
    assert (a["rwkv6-7b"].layers, a["rwkv6-7b"].d_model,
            a["rwkv6-7b"].d_ff, a["rwkv6-7b"].vocab) == \
        (32, 4096, 14336, 65536)
    assert (a["yi-34b"].layers, a["yi-34b"].d_model, a["yi-34b"].n_heads,
            a["yi-34b"].kv_heads, a["yi-34b"].d_ff, a["yi-34b"].vocab) == \
        (60, 7168, 56, 8, 20480, 64000)
    assert (a["zamba2-2.7b"].layers, a["zamba2-2.7b"].d_model,
            a["zamba2-2.7b"].ssm_state) == (54, 2560, 64)
    assert (a["deepseek-moe-16b"].n_experts, a["deepseek-moe-16b"].top_k,
            a["deepseek-moe-16b"].shared_experts) == (64, 6, 2)
    assert (a["granite-moe-1b-a400m"].n_experts,
            a["granite-moe-1b-a400m"].top_k) == (32, 8)
    assert (a["gemma2-27b"].layers, a["gemma2-27b"].d_model,
            a["gemma2-27b"].d_ff, a["gemma2-27b"].vocab) == \
        (46, 4608, 36864, 256000)
    assert (a["seamless-m4t-medium"].encoder_layers,
            a["seamless-m4t-medium"].vocab) == (12, 256206)
    assert (a["llama3-8b"].kv_heads, a["llama3-8b"].vocab) == (8, 128256)
    assert (a["stablelm-1.6b"].d_ff, a["stablelm-1.6b"].vocab) == \
        (5632, 100352)
    assert (a["llava-next-34b"].frontend,
            a["llava-next-34b"].d_model) == ("patches", 7168)


def test_long_context_skip_policy():
    """long_500k runs only for sub-quadratic archs (DESIGN.md)."""
    subq = {n for n, c in ARCHS.items() if c.sub_quadratic}
    assert subq == {"rwkv6-7b", "zamba2-2.7b"}
    for n, c in ARCHS.items():
        names = [s.name for s in c.shapes()]
        if n in subq:
            assert "long_500k" in names
        else:
            assert "long_500k" not in names
            assert dict(c.skipped_shapes()).get("long_500k")


def test_rwkv_pallas_scan_path_matches_jax():
    """Opt-in Pallas WKV path in the model == the pure-JAX chunked path."""
    cfg = get_config("rwkv6-7b", smoke=True).replace(ssd_chunk=8)
    model_jax = build_model(cfg)
    model_pl = build_model(cfg.replace(use_pallas_scan=True))
    params = model_jax.init_params(KEY)
    batch = _batch(cfg, S=12)
    l1 = model_jax.loss(params, batch, remat=False)
    l2 = model_pl.loss(params, batch, remat=False)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_moe_gathered_dispatch_matches_dense():
    """§Perf B3: sort-based capacity dispatch == dense one-hot dispatch
    at ample capacity (no drops)."""
    from repro.models import moe as MOE
    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    p = MOE.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model)) * 0.5
    y_dense, _ = MOE.moe_apply(p, cfg, x)
    y_gath, _ = MOE.moe_apply_gathered(p, cfg, x, capacity_factor=8.0)
    assert float(jnp.max(jnp.abs(y_dense - y_gath))) < 1e-4
    # tight capacity drops tokens but stays finite and close in norm
    y_tight, _ = MOE.moe_apply_gathered(p, cfg, x, capacity_factor=1.0)
    assert bool(jnp.all(jnp.isfinite(y_tight)))
    # the config knob routes through moe_apply
    cfg_g = cfg.replace(moe_dispatch="gathered")
    from repro.models import build_model
    m = build_model(cfg_g)
    params = m.init_params(KEY)
    loss = m.loss(params, _batch(cfg_g), remat=False)
    assert jnp.isfinite(loss)
