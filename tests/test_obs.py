"""Observability subsystem: tracer, registry, fidelity recorder.

Covers the contracts the rest of the repo leans on: span nesting and
JSONL round-trips, virtual-clock replay determinism, scoped registry
reset, the registry-backed ``solver_stats()``/``axis_cache_stats()``
shims, store-counter mirroring, scheduler tick/request spans, the
NaN-safe metrics summary, and a small fidelity replay.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.obs.registry import Registry, get_registry
from repro.obs.tracing import NULL_SPAN, Tracer, get_tracer, set_tracer
from repro.obs.tracing import span as obs_span
from repro.obs.tracing import trace_event


# ---------------------------------------------------------------- tracer
class TestTracer:
    def test_nesting_parents(self):
        tr = Tracer()
        with tr.span("outer"):
            with tr.span("inner"):
                tr.event("leaf")
        outer, inner, leaf = tr.spans
        assert outer.parent is None
        assert inner.parent == outer.sid
        assert leaf.parent == inner.sid
        assert leaf.t0 == leaf.t1                    # zero-length event
        assert [s.name for s in tr.children(outer)] == ["inner"]

    def test_siblings_share_parent(self):
        tr = Tracer()
        with tr.span("p"):
            with tr.span("a"):
                pass
            with tr.span("b"):
                pass
        p, a, b = tr.spans
        assert a.parent == p.sid and b.parent == p.sid

    def test_detached_span_straddles_stack(self):
        """Detached spans (per-request lifecycle) record a parent but
        never become the implicit parent of stacked spans."""
        tr = Tracer()
        with tr.span("tick0"):
            req = tr.start("request", detached=True, req_id=7)
        with tr.span("tick1"):
            pass
        tr.end(req, n=3)
        names = {s.name: s for s in tr.spans}
        assert names["request"].parent == names["tick0"].sid
        assert names["tick1"].parent is None         # not under "request"
        assert names["request"].t1 >= names["tick1"].t1
        assert names["request"].attrs == {"req_id": 7, "n": 3}

    def test_virtual_clock_replay_determinism(self):
        """Two runs on the same fake clock serialize identically."""
        def run():
            t = [0.0]

            def clock():
                t[0] += 0.125
                return t[0]

            tr = Tracer(clock=clock)
            with tr.span("solve", dims=[4, 4, 4]):
                tr.event("node", depth=2)
            return tr.dumps_jsonl()

        assert run() == run()
        spans = [json.loads(l) for l in run().splitlines()]
        assert [s["t0"] for s in spans] == [0.125, 0.25]

    def test_jsonl_round_trip(self, tmp_path):
        tr = Tracer()
        with tr.span("a", k="v", n=2):
            tr.event("b")
        path = tmp_path / "spans.jsonl"
        tr.to_jsonl(path)
        back = Tracer.from_jsonl(path)
        assert len(back) == 2
        assert [(s.sid, s.parent, s.name, s.attrs) for s in back] == \
            [(s.sid, s.parent, s.name, s.attrs) for s in tr.spans]
        assert back[0].duration == pytest.approx(tr.spans[0].duration)

    def test_module_level_span_null_when_disabled(self):
        assert get_tracer() is None
        cm = obs_span("anything", k=1)
        assert cm is NULL_SPAN and not cm
        with cm as sp:
            assert sp is None
        assert trace_event("nothing") is None

    def test_set_tracer_returns_previous(self):
        t1, t2 = Tracer(), Tracer()
        assert set_tracer(t1) is None
        assert set_tracer(t2) is t1
        with obs_span("x") as sp:
            assert sp is not None
        assert [s.name for s in t2.spans] == ["x"]
        assert t1.spans == []
        set_tracer(None)


# -------------------------------------------------------------- registry
class TestRegistry:
    def test_counters_and_scoped_reset(self):
        reg = Registry()
        reg.inc("a.x")
        reg.inc("a.y", 4)
        reg.inc("b.z")
        reg.set_gauge("a.g", 0.5)
        assert reg.counters("a.") == {"a.x": 1, "a.y": 4}
        reg.reset("a.")
        # counters zero in place (keys survive); gauges are deleted
        assert reg.counters("a.") == {"a.x": 0, "a.y": 0}
        assert reg.get("b.z") == 1
        assert reg.gauges() == {}
        reg.reset()
        assert all(v == 0 for v in reg.snapshot().values())

    def test_snapshot_merges_sorted(self):
        reg = Registry()
        reg.inc("z.c")
        reg.set_gauge("a.g", 2.0)
        assert list(reg.snapshot()) == ["a.g", "z.c"]

    def test_solver_stats_shim_reads_registry(self):
        from repro.core import EYERISS_LIKE, Gemm
        from repro.core.solver import (reset_solver_stats, solve,
                                       solver_stats)

        reset_solver_stats()
        assert solver_stats() == {"calls": 0}
        solve(Gemm(16, 16, 16, name="t"), EYERISS_LIKE)
        assert solver_stats() == {"calls": 1}
        assert get_registry().get("solver.calls") == 1
        reset_solver_stats()
        assert solver_stats() == {"calls": 0}

    def test_axis_cache_stats_shim(self):
        from repro.core import EYERISS_LIKE, Gemm
        from repro.core.solver import (axis_cache_stats, clear_axis_cache,
                                       solve)

        clear_axis_cache()
        solve(Gemm(24, 24, 24, name="t"), EYERISS_LIKE)
        st = axis_cache_stats()
        assert st["misses"] > 0 and st["entries"] == st["misses"]
        solve(Gemm(24, 24, 24, name="t2"), EYERISS_LIKE)
        assert axis_cache_stats()["hits"] > 0
        clear_axis_cache()
        assert axis_cache_stats() == {"hits": 0, "misses": 0,
                                      "entries": 0}

    def test_store_counters_mirrored(self, tmp_path):
        from repro.core import EYERISS_LIKE, Gemm
        from repro.planner import PlanStore
        from repro.planner.batch import BatchPlanner

        store = PlanStore(tmp_path / "db")
        planner = BatchPlanner(store)
        rows = [("qkv", Gemm(16, 48, 16, name="qkv"), 1)]
        planner.plan_gemms(rows, EYERISS_LIKE)
        planner.plan_gemms(rows, EYERISS_LIKE)
        reg = get_registry()
        assert reg.get("plan_store.misses") == store.misses == 1
        assert reg.get("plan_store.hits") == store.hits == 1
        assert reg.get("plan_store.puts") == store.puts == 1
        assert reg.get("planner.batches") == 2


# ------------------------------------------------------------- scheduler
@pytest.mark.slow
class TestSchedulerSpans:
    def test_tick_and_request_spans(self):
        jax = pytest.importorskip("jax")
        from repro.configs import get_config
        from repro.models import build_model
        from repro.serving import Engine, ServeConfig
        from repro.serving.sched import (ContinuousScheduler, Request,
                                         SchedConfig, TraceClock, replay)

        cfg = get_config("llama3-8b", smoke=True)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        engine = Engine(model, params,
                        ServeConfig(max_new_tokens=4, cache_len=32))
        rng = np.random.default_rng(0)
        reqs = [Request(req_id=i,
                        tokens=rng.integers(0, cfg.vocab, (6,)),
                        max_new_tokens=4, arrival_s=0.01 * i)
                for i in range(2)]
        tr = Tracer()
        set_tracer(tr)
        ticks = []
        try:
            clock = TraceClock()
            sched = ContinuousScheduler(
                engine, SchedConfig(slots=2, chunk_widths=(4, 8)),
                clock=clock.now,
                on_tick=lambda s: ticks.append(s.metrics.steps))
            results = replay(sched, reqs, clock)
        finally:
            set_tracer(None)
        assert len(results) == 2
        names = [s.name for s in tr.spans]
        assert names.count("sched.tick") == sched.metrics.steps
        assert names.count("sched.request") == 2
        assert names.count("sched.first_token") == 2
        assert "sched.decode_batch" in names and \
            "sched.prefill_chunk" in names
        for rs in tr.by_name("sched.request"):
            assert rs.t1 is not None
            assert rs.attrs["n_generated"] == 4
            kids = [s.name for s in tr.children(rs)]
            assert kids == ["sched.first_token"]
        # on_tick fired once per step, after the tick span closed
        assert ticks == list(range(1, sched.metrics.steps + 1))
        reg = get_registry()
        assert reg.get("sched.ticks") == sched.metrics.steps
        assert reg.get("sched.finished") == 2
        assert reg.get("sched.tokens") == 8
        assert reg.get("sched.padded_decode_rows") == \
            sched.metrics.padded_decode_rows


# --------------------------------------------------------------- metrics
class TestMetrics:
    def test_tpot_nan_safe_and_padded_rows(self):
        from repro.serving.sched.metrics import ServingMetrics
        from repro.serving.sched.requests import RequestResult

        m = ServingMetrics()
        m.record_result(RequestResult(
            req_id=0, tokens=[5], finish_reason="length", prompt_len=4,
            arrival_s=0.0, first_token_s=0.1, finish_s=0.1))
        m.record_tick(active=1, slots=4, decoded=True, chunks=0,
                      padded_tokens=0, padded_rows=3)
        m.record_tick(active=2, slots=4, decoded=True, chunks=1,
                      padded_tokens=4, padded_rows=2)
        s = m.summary()
        # single-token request: no tpot samples -> 0.0, never NaN
        assert s["tpot_p50_s"] == 0.0 and s["tpot_p95_s"] == 0.0
        assert s["padded_decode_rows"] == 5
        assert json.loads(json.dumps(s)) == s


# -------------------------------------------------------------- fidelity
class TestFidelity:
    def test_spearman(self):
        from repro.obs.fidelity import spearman

        assert spearman([1, 2, 3], [10, 20, 30]) == pytest.approx(1.0)
        assert spearman([1, 2, 3], [30, 20, 10]) == pytest.approx(-1.0)
        assert spearman([1.0], [5.0]) == 1.0          # degenerate: <2 pts
        assert spearman([1, 1, 1], [1, 2, 3]) == 0.0  # one side constant
        assert spearman([2, 2], [7, 7]) == 1.0        # both constant
        # monotone under ties
        assert spearman([1, 2, 2, 3], [1, 2, 3, 4]) > 0.9

    @pytest.mark.slow
    def test_replay_records_and_gates(self, tmp_path):
        pytest.importorskip("jax")
        from repro.obs.fidelity import (load_rows, record_rows,
                                        replay_manifest)
        from repro.planner.manifest import (ManifestEntry,
                                            ModelMappingManifest)

        shapes = [(128, 256, 256), (256, 512, 512), (512, 1024, 1024)]
        entries = [ManifestEntry(
            gemm_type="mlp", dims=d, weight=1, digest=f"e{i}",
            objective=0.0, feasible=True, solve_time_s=0.0,
            cached=False) for i, d in enumerate(shapes)]
        # an infeasible entry must be skipped, a duplicate-dims entry
        # must reuse the measurement under its own family
        entries.append(ManifestEntry(
            gemm_type="skip", dims=(8, 8, 8), weight=1, digest="bad",
            objective=0.0, feasible=False, solve_time_s=0.0,
            cached=False))
        entries.append(ManifestEntry(
            gemm_type="attn", dims=shapes[0], weight=3, digest="dup",
            objective=0.0, feasible=True, solve_time_s=0.0,
            cached=False))
        manifest = ModelMappingManifest(
            model="t", hw_name="tpuv5e-like", objective="energy",
            prefill_seqs=(), decode_batches=(), cache_len=0,
            entries=entries)
        rep = replay_manifest(manifest, repeats=2, warmup=1,
                              interpret=True, gate=0.9)
        assert len(rep.rows) == 4                     # 3 + dup, no skip
        assert rep.rows[-1].measured_time_s == \
            rep.rows[0].measured_time_s                # reused measurement
        assert rep.rows[-1].gemm_type == "attn"
        assert {r.gemm_type for r in rep.rows} == {"mlp", "attn"}
        assert all(np.isfinite(r.measured_rel_rank_error)
                   for r in rep.rows)
        assert all(lvl in r.predicted_bytes_per_level
                   for r in rep.rows for lvl in ("dram", "sram", "rf"))
        # "attn" has 1 row < min_family: reported, not gated
        assert "attn" in rep.families
        assert set(rep.gated_families) == {"all", "mlp"}
        assert rep.passes(), rep.summary()

        path = record_rows(rep, tmp_path, "t")
        assert path == tmp_path / "fidelity" / "t.jsonl"
        summary, rows = load_rows(path)
        assert summary["rows"] == 4 and summary["passes"] is True
        assert [r.plan_key for r in rows] == \
            [r.plan_key for r in rep.rows]
        assert rows[0].dims == shapes[0]
