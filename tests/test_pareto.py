"""Certified (energy, delay) Pareto frontiers: the latency model, the
deterministic non-dominance filter, the epsilon-constraint sweep, its
plan-store section, and the ERT calibration gate."""
import dataclasses

import numpy as np
import pytest

from repro.core import TEMPLATES, Gemm, Mapping
from repro.core.edp import evaluate, latency
from repro.core.hardware import (BANDWIDTHS, Bandwidth, bandwidth_for,
                                 INFINITE_BANDWIDTH)
from repro.core.pareto import (ParetoPoint, pareto_min,
                               select_frontier_point, verify_pareto)
from repro.core.solver import (achievable_spatial_levels, solve,
                               solve_pareto, solver_stats)
from repro.core.timeloop_ref import reference_counts
from repro.planner.batch import cached_solve_pareto
from repro.planner.store import (ParetoPlanEntry, PlanStore,
                                 pareto_certificate_from_json,
                                 pareto_certificate_to_json,
                                 pareto_plan_key)

EYE = TEMPLATES["eyeriss-like"]
GEM = TEMPLATES["gemmini-like"]


# ---------------------------------------------------------------------------
# pareto_min: deterministic non-dominance filter
# ---------------------------------------------------------------------------

def test_pareto_min_drops_dominated_and_orders():
    pts = [(3.0, 1.0, "c"), (1.0, 3.0, "a"), (2.0, 2.0, "b"),
           (2.5, 2.5, "dominated")]
    out = pareto_min(pts, key_a=lambda p: p[0], key_b=lambda p: p[1])
    assert [p[2] for p in out] == ["a", "b", "c"]
    # ascending a, strictly descending b
    assert [p[0] for p in out] == sorted(p[0] for p in out)
    assert all(x[1] > y[1] for x, y in zip(out, out[1:]))


def test_pareto_min_equal_points_collapse_to_tie_minimal():
    pts = [(1.0, 1.0, "z"), (1.0, 1.0, "a"), (1.0, 1.0, "m")]
    for perm in ([0, 1, 2], [2, 1, 0], [1, 2, 0]):
        out = pareto_min([pts[i] for i in perm], key_a=lambda p: p[0],
                         key_b=lambda p: p[1], tie=lambda p: p[2])
        assert [p[2] for p in out] == ["a"]


def test_pareto_min_equal_b_keeps_smaller_a():
    # the codesign tie rule: among equal-EDP designs, smaller area wins
    pts = [(5.0, 2.0), (3.0, 2.0), (4.0, 2.0)]
    out = pareto_min(pts, key_a=lambda p: p[0], key_b=lambda p: p[1])
    assert out == [(3.0, 2.0)]


def test_codesign_frontier_tie_determinism():
    from repro.core.codesign import DesignPoint, pareto_frontier
    mk = lambda npe, s, r, area, edp: DesignPoint(      # noqa: E731
        npe, s, r, area, edp, 1.0, True)
    # two designs with identical (area, edp): the lexicographically
    # smaller (num_pe, sram, rf) config must survive, whatever the order
    a = mk(64, 1024, 64, 100.0, 2.0)
    b = mk(256, 512, 32, 100.0, 2.0)
    cheaper = mk(32, 256, 16, 50.0, 3.0)
    for order in ([a, b, cheaper], [b, cheaper, a], [cheaper, a, b]):
        front = pareto_frontier(order)
        assert front == [cheaper, a]


# ---------------------------------------------------------------------------
# latency model (tentpole: delay is bytes/bandwidth-aware)
# ---------------------------------------------------------------------------

def test_latency_matches_reference_counts_by_hand():
    gemm = Gemm(64, 64, 64)
    m = Mapping((32, 32, 32), (16, 16, 1), (1, 1, 1), "z", "z")
    counts = reference_counts(gemm, m, full_reuse=True)
    bw = bandwidth_for(EYE)
    assert bw == BANDWIDTHS["eyeriss-like"]
    lat = latency(gemm, m, EYE)
    npe = m.num_pe_used
    assert lat.compute_cycles == gemm.volume / npe
    assert lat.dram_cycles == (counts.dram_read
                               + counts.dram_write) / bw.dram
    assert lat.sram_cycles == (counts.sram_read
                               + counts.sram_write) / bw.sram
    assert lat.rf_cycles == (counts.rf_read
                             + counts.rf_write) / (bw.rf * npe)
    assert lat.cycles == max(lat.compute_cycles, lat.dram_cycles,
                             lat.sram_cycles, lat.rf_cycles)
    assert lat.delay_ns == lat.cycles * EYE.cycle_ns
    assert lat.bound in ("compute", "dram", "sram", "rf")


def test_latency_infinite_bandwidth_recovers_compute_bound():
    gemm = Gemm(64, 64, 64)
    m = Mapping((32, 32, 32), (16, 16, 1), (1, 1, 1), "z", "z")
    unlisted = dataclasses.replace(EYE, name="not-in-the-table")
    assert bandwidth_for(unlisted) == INFINITE_BANDWIDTH
    lat = latency(gemm, m, unlisted)
    assert lat.bound == "compute"
    assert lat.delay_ns == gemm.volume / m.num_pe_used * EYE.cycle_ns
    # explicit bw override beats the table
    lat2 = latency(gemm, m, EYE, bw=Bandwidth())
    assert lat2.delay_ns == lat.delay_ns


def test_bandwidth_kept_out_of_spec_identity():
    """Bandwidth lives in a name-keyed side table, NOT on the spec:
    plan-store digests derive from the spec dict and must not re-key."""
    assert not any(f.name in ("bandwidth", "bw")
                   for f in dataclasses.fields(EYE))
    assert bandwidth_for(EYE).dram < float("inf")
    # DSE sweep names fall back to infinite (compute-only delay)
    dse = dataclasses.replace(EYE, name="dse_64_65536_64")
    assert bandwidth_for(dse) == INFINITE_BANDWIDTH
    # overrides hook (calibration installs through here)
    ov = {EYE.name: Bandwidth(1.0, 2.0, 3.0)}
    assert bandwidth_for(EYE, overrides=ov) == Bandwidth(1.0, 2.0, 3.0)


def test_evaluate_delay_at_least_compute_bound():
    gemm = Gemm(64, 96, 128)
    res = solve(gemm, EYE, spatial_mode="le")
    rep = evaluate(gemm, res.mapping, EYE)
    assert rep.delay_ns >= (gemm.volume / res.mapping.num_pe_used
                            * EYE.cycle_ns)
    assert rep.edp == pytest.approx(
        rep.energy_pj * 1e-12 * rep.delay_ns * 1e-9)


# ---------------------------------------------------------------------------
# min_pe constraint (the epsilon slices)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["vectorized", "reference"])
def test_min_pe_respected_and_engines_agree(engine):
    gemm = Gemm(64, 96, 128)
    base = solve(gemm, EYE, spatial_mode="le", engine=engine)
    assert base.mapping.num_pe_used == 128
    res = solve(gemm, EYE, spatial_mode="le", min_pe=192, engine=engine)
    assert res.mapping.num_pe_used >= 192
    assert res.certificate.gap == 0.0
    # constrained optimum can only cost more
    assert res.certificate.objective >= base.certificate.objective
    # both engines must agree bit-for-bit under the constraint
    other = solve(gemm, EYE, spatial_mode="le", min_pe=192,
                  engine="reference" if engine == "vectorized"
                  else "vectorized")
    assert other.mapping == res.mapping
    assert other.certificate.objective == res.certificate.objective


def test_min_pe_none_and_one_are_unconstrained():
    gemm = Gemm(48, 80, 112)
    a = solve(gemm, EYE, spatial_mode="le")
    b = solve(gemm, EYE, spatial_mode="le", min_pe=None)
    c = solve(gemm, EYE, spatial_mode="le", min_pe=1)
    assert a.mapping == b.mapping == c.mapping
    assert (a.certificate.objective == b.certificate.objective
            == c.certificate.objective)


def test_min_pe_infeasible_floor():
    res = solve(Gemm(8, 8, 8), EYE, spatial_mode="le", min_pe=10 ** 9)
    assert res.mapping is None and not res.certificate.feasible


def test_achievable_spatial_levels():
    levels = achievable_spatial_levels(Gemm(4, 6, 1), 12)
    # products of divisors of (4, 6, 1) capped at 12
    assert levels == [1, 2, 3, 4, 6, 8, 12]
    assert achievable_spatial_levels(Gemm(64, 96, 128), EYE.num_pe)[-1] \
        <= EYE.num_pe


# ---------------------------------------------------------------------------
# solve_pareto (the certified frontier)
# ---------------------------------------------------------------------------

def test_solve_pareto_endpoint_bit_matches_solve():
    gemm = Gemm(96, 56, 72)
    res = solve_pareto(gemm, EYE, spatial_mode="le")
    base = solve(gemm, EYE, spatial_mode="le")
    ep = res.certificate.energy_optimal
    assert ep.mapping == base.mapping
    assert ep.certificate.objective == base.certificate.objective
    assert ep.min_pe is None


def test_solve_pareto_nondominated_and_verified():
    gemm = Gemm(96, 56, 72)
    res = solve_pareto(gemm, EYE, spatial_mode="le")
    pts = res.certificate.points
    assert len(pts) >= 2, "expected a real trade-off on this shape"
    for a, b in zip(pts, pts[1:]):
        assert b.energy_pj >= a.energy_pj
        assert b.delay_ns < a.delay_ns
    for p in pts:
        assert p.min_pe is None or p.num_pe_used >= p.min_pe
        assert p.certificate.gap == 0.0
    assert verify_pareto(res.certificate, EYE)
    # tampering is caught
    bad = dataclasses.replace(res.certificate,
                              points=tuple(
                                  dataclasses.replace(p, delay_ns=1.0)
                                  for p in res.certificate.points))
    assert not verify_pareto(bad, EYE)
    assert not verify_pareto(res.certificate, GEM)   # wrong spec


def test_solve_pareto_equality_mode_single_point():
    res = solve_pareto(Gemm(64, 64, 64), EYE)   # default mode: equality
    assert res.certificate.spatial_mode == "equality"
    assert len(res.certificate.points) == 1
    assert verify_pareto(res.certificate, EYE)


def test_solve_pareto_infeasible():
    # prime extents cannot tile the 16x16 array exactly: an explicitly
    # requested equality mode is infeasible, so the frontier is empty
    # (only a *defaulted* equality falls back to "le")
    res = solve_pareto(Gemm(7, 7, 7), EYE, spatial_mode="equality")
    assert not res.certificate.feasible
    assert res.certificate.points == ()
    assert res.certificate.energy_optimal is None
    assert verify_pareto(res.certificate, EYE)


def test_solve_pareto_max_points_thinning():
    gemm = Gemm(96, 56, 72)
    full = solve_pareto(gemm, EYE, spatial_mode="le", max_points=None)
    thin = solve_pareto(gemm, EYE, spatial_mode="le", max_points=2)
    assert thin.certificate.levels_swept <= 2
    assert thin.certificate.levels_total == full.certificate.levels_total
    # the energy-optimal endpoint survives thinning bit-for-bit
    assert (thin.certificate.energy_optimal.mapping
            == full.certificate.energy_optimal.mapping)
    assert verify_pareto(thin.certificate, EYE)


# ---------------------------------------------------------------------------
# select_frontier_point
# ---------------------------------------------------------------------------

def _pt(e, t, npe, floor=None):
    return ParetoPoint(min_pe=floor, mapping=None, certificate=None,
                       energy_pj=e, delay_ns=t, edp=e * t, num_pe_used=npe)


def test_select_frontier_point_rules():
    pts = [_pt(1.0, 100.0, 64), _pt(2.0, 50.0, 128), _pt(4.0, 25.0, 256)]
    assert select_frontier_point(pts, None) is pts[0]       # energy-opt
    assert select_frontier_point(pts, 60.0) is pts[1]       # cheapest ok
    assert select_frontier_point(pts, 25.0) is pts[2]       # exactly met
    assert select_frontier_point(pts, 10.0) is pts[2]       # best effort
    assert select_frontier_point([], 10.0) is None


# ---------------------------------------------------------------------------
# plan-store pareto section
# ---------------------------------------------------------------------------

def test_pareto_certificate_json_roundtrip():
    res = solve_pareto(Gemm(96, 56, 72, "rt"), EYE, spatial_mode="le")
    c = res.certificate
    back = pareto_certificate_from_json(pareto_certificate_to_json(c))
    assert back == c
    assert verify_pareto(back, EYE)


def test_pareto_key_includes_bandwidth():
    gemm = Gemm(16, 16, 16)
    k1 = pareto_plan_key(gemm, EYE)
    k2 = pareto_plan_key(gemm, EYE, bw=Bandwidth(1.0, 2.0, 3.0))
    assert k1.digest != k2.digest       # recalibration re-keys frontiers
    # infinite bandwidth (unlisted spec) round-trips through strict JSON
    k3 = pareto_plan_key(gemm, dataclasses.replace(EYE, name="unlisted"))
    assert k3.bandwidth == (float("inf"),) * 3
    assert k3.digest != k1.digest


def test_pareto_store_roundtrip_and_fsck(tmp_path):
    gemm = Gemm(96, 56, 72, "store")
    store = PlanStore(tmp_path)
    key = pareto_plan_key(gemm, EYE, spatial_mode="le")
    assert store.get_pareto(key) is None
    assert not store.contains_pareto(key)

    res = cached_solve_pareto(gemm, EYE, spatial_mode="le", store=store)
    assert store.contains_pareto(key)
    assert store.num_pareto() == 1
    assert store.stats()["pareto_entries"] == 1

    entry = store.get_pareto(key)
    assert entry.certificate == res.certificate
    assert entry.points == res.certificate.points
    assert entry.feasible

    # cold store object re-reads from disk
    store2 = PlanStore(tmp_path)
    entry2 = store2.get_pareto(key)
    assert entry2.certificate == res.certificate
    assert verify_pareto(entry2.certificate, EYE)
    report = store2.fsck()
    assert report["corrupt"] == [] and report["ok"] == report["checked"]


def test_pareto_store_hit_skips_all_solves(tmp_path):
    gemm = Gemm(64, 96, 128, "hit")
    store = PlanStore(tmp_path)
    miss = cached_solve_pareto(gemm, EYE, spatial_mode="le", store=store)
    assert miss.n_solves >= 1
    before = solver_stats()["calls"]
    hit = cached_solve_pareto(gemm, EYE, spatial_mode="le",
                              store=PlanStore(tmp_path))
    assert solver_stats()["calls"] == before          # zero solver calls
    assert hit.n_solves == 0
    assert hit.certificate == miss.certificate


def test_pareto_corrupt_entry_quarantined(tmp_path):
    gemm = Gemm(64, 96, 128, "corrupt")
    store = PlanStore(tmp_path)
    cached_solve_pareto(gemm, EYE, store=store)
    [path] = list((store.root / "pareto").glob("*/*.json"))
    path.write_text(path.read_text()[:-40])           # torn write
    fresh = PlanStore(tmp_path)
    report = fresh.fsck()
    assert len(report["corrupt"]) == 1
    key = pareto_plan_key(gemm, EYE)
    assert fresh.get_pareto(key) is None              # quarantined
    assert fresh.num_quarantined() == 1
    # a re-solve heals the store
    again = cached_solve_pareto(gemm, EYE, store=fresh)
    assert again.n_solves >= 1
    assert PlanStore(tmp_path).fsck()["corrupt"] == []


# ---------------------------------------------------------------------------
# calibration gate
# ---------------------------------------------------------------------------

def _synthetic_rows(n=18, ns_per_macc=0.002, ns_per_dram_byte=0.05):
    from repro.obs.fidelity import FidelityRow
    rows = []
    for i in range(n):
        M, N, K = 8 * (i + 1), 16, 32
        bpl = {"dram": 100.0 * (i + 1) ** 2, "sram": 10.0 * (i + 1),
               "rf": 5.0}
        t_ns = ns_per_macc * M * N * K + ns_per_dram_byte * bpl["dram"]
        rows.append(FidelityRow(
            plan_key=f"k{i}", manifest_digest=f"m{i}", gemm_type="s",
            dims=(M, N, K), weight=1, predicted_energy=1.0,
            predicted_bytes_per_level=bpl, measured_time_s=t_ns * 1e-9))
    return rows


def test_calibration_beats_compute_only_baseline():
    from repro.obs.calibrate import fit_rows
    rep = fit_rows(_synthetic_rows())
    assert rep.passes()
    assert rep.improvement > 0.5
    assert rep.model.ns_per_macc == pytest.approx(0.002, rel=1e-3)
    assert rep.model.ns_per_byte["dram"] == pytest.approx(0.05, rel=1e-3)
    assert rep.model.ns_per_byte["rf"] >= 0.0


def test_calibration_compute_only_data_does_not_regress():
    """On purely compute-bound data the calibrated model must tie the
    baseline (gate passes) — calibration never makes delay worse."""
    from repro.obs.calibrate import fit_rows
    rep = fit_rows(_synthetic_rows(ns_per_dram_byte=0.0))
    assert rep.passes()
    assert rep.holdout_err <= rep.baseline_holdout_err + 1e-12


def test_calibration_bandwidth_and_persistence(tmp_path):
    from repro.obs.calibrate import (calibrated_overrides, fit_rows,
                                     load_calibration, save_calibration)
    rep = fit_rows(_synthetic_rows())
    bw = rep.model.bandwidth(EYE.cycle_ns, dtype_bytes=2)
    assert bw.dram == pytest.approx(
        EYE.cycle_ns / (rep.model.ns_per_byte["dram"] * 2), rel=1e-9)
    assert bw.rf == float("inf")        # rf never the bottleneck here
    path = save_calibration(tmp_path, "cal", EYE.name, rep)
    models = load_calibration(path)
    assert models[EYE.name] == rep.model
    ov = calibrated_overrides(path,
                              cycle_ns_by_spec={EYE.name: EYE.cycle_ns})
    assert bandwidth_for(EYE, overrides=ov) == bw
    # the override changes delay through the standard evaluate path
    gemm = Gemm(64, 64, 64)
    m = Mapping((32, 32, 32), (16, 16, 1), (1, 1, 1), "z", "z")
    rep_cal = evaluate(gemm, m, EYE, bw=ov[EYE.name])
    assert rep_cal.delay_ns != evaluate(gemm, m, EYE).delay_ns


def test_calibration_needs_enough_rows():
    from repro.obs.calibrate import fit_rows
    with pytest.raises(ValueError, match="rows"):
        fit_rows(_synthetic_rows(n=3))


def test_calibration_deterministic():
    from repro.obs.calibrate import fit_rows
    a, b = fit_rows(_synthetic_rows()), fit_rows(_synthetic_rows())
    assert a.model == b.model and a.holdout_err == b.holdout_err
