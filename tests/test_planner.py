"""Planner subsystem: store round-trips, cache semantics, key stability
across processes, parallel==sequential batch solves, warm-started
branch-and-bound soundness, and store/manifest-driven kernel dispatch."""
import json
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent

from repro.core import Gemm, TEMPLATES, solve, verify
from repro.core.hardware import AcceleratorSpec, Ert
from repro.core.workloads import LlmSpec, prefill_gemms, scenario_gemms
from repro.planner import (BatchPlanner, ModelMappingManifest, PlanStore,
                           cached_solve, plan_key)
from repro.planner.store import PlanEntry

ERT = Ert(dram_read=200.0, dram_write=200.0, sram_read=6.0, sram_write=6.5,
          rf_read=1.0, rf_write=1.1, macc=2.0, sram_leak=0.1,
          rf_leak=0.001)
HW = AcceleratorSpec(name="tiny4", sram_words=96, rf_words=8, num_pe=4,
                     ert=ERT)
TINY = LlmSpec("tiny", layers=2, d_model=64, n_heads=4, kv_heads=2,
               head_dim=16, d_ff=128, vocab=512)


def test_store_round_trip(tmp_path):
    """save -> load (fresh store object) -> identical Mapping/objective."""
    store = PlanStore(tmp_path)
    gemm = Gemm(8, 8, 8)
    res = cached_solve(gemm, HW, store=store)
    assert res.mapping is not None

    store2 = PlanStore(tmp_path)      # fresh in-memory cache, same disk
    entry = store2.get(plan_key(gemm, HW))
    assert entry is not None
    assert entry.mapping == res.mapping
    assert entry.certificate.objective == res.certificate.objective
    assert entry.certificate.upper_bound == res.certificate.upper_bound
    assert entry.certificate.lower_bound == res.certificate.lower_bound
    assert entry.hw == HW             # specs are self-describing
    assert verify(entry.certificate, entry.hw)


def test_cache_hit_miss_semantics(tmp_path):
    store = PlanStore(tmp_path)
    gemm = Gemm(8, 4, 4)
    key = plan_key(gemm, HW)
    assert store.get(key) is None and store.misses == 1
    cached_solve(gemm, HW, store=store)       # miss -> solve -> put
    assert store.puts == 1
    res2 = cached_solve(gemm, HW, store=store)
    assert store.puts == 1 and store.hits >= 1   # served from cache
    # different objective / walk restriction / dims are distinct keys
    assert plan_key(gemm, HW, objective="edp").digest != key.digest
    assert plan_key(gemm, HW,
                    allowed_walk01=("z",)).digest != key.digest
    assert plan_key(Gemm(8, 4, 2), HW).digest != key.digest
    # hw name is metadata, not identity
    import dataclasses
    renamed = dataclasses.replace(HW, name="other")
    assert plan_key(gemm, renamed).digest == key.digest
    assert res2.certificate.feasible


def test_key_stability_across_processes(tmp_path):
    """The content hash must be reproducible in a fresh interpreter."""
    code = (
        f"import sys; sys.path.insert(0, {str(ROOT / 'src')!r})\n"
        "from repro.core import Gemm\n"
        "from repro.core.hardware import AcceleratorSpec, Ert\n"
        "from repro.planner import plan_key\n"
        "ert = Ert(dram_read=200.0, dram_write=200.0, sram_read=6.0,\n"
        "          sram_write=6.5, rf_read=1.0, rf_write=1.1, macc=2.0,\n"
        "          sram_leak=0.1, rf_leak=0.001)\n"
        "hw = AcceleratorSpec(name='tiny4', sram_words=96, rf_words=8,\n"
        "                     num_pe=4, ert=ert)\n"
        "print(plan_key(Gemm(8, 8, 8), hw).digest)\n")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True)
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == plan_key(Gemm(8, 8, 8), HW).digest


def test_parallel_equals_sequential(tmp_path):
    gemms = prefill_gemms(TINY, 96)
    seq_store = PlanStore(tmp_path / "seq")
    par_store = PlanStore(tmp_path / "par")
    e_seq = BatchPlanner(seq_store, jobs=1).plan_gemms(gemms, HW)
    e_par = BatchPlanner(par_store, jobs=2).plan_gemms(gemms, HW)
    assert len(e_seq) == len(e_par) > 0
    for a, b in zip(sorted(e_seq, key=lambda e: e.digest),
                    sorted(e_par, key=lambda e: e.digest)):
        assert a.digest == b.digest
        assert a.objective == b.objective
        sa = seq_store.get(a.digest)
        sb = par_store.get(b.digest)
        assert sa.mapping == sb.mapping


def test_batch_cold_then_warm(tmp_path):
    store = PlanStore(tmp_path)
    planner = BatchPlanner(store, jobs=1)
    man1 = planner.plan_model(TINY, HW, prefill_seqs=(64, 128),
                              decode_batches=(4,), cache_len=256)
    rep1 = planner.last_report
    assert rep1.solved == rep1.unique_gemms and rep1.hits == 0
    man2 = planner.plan_model(TINY, HW, prefill_seqs=(64, 128),
                              decode_batches=(4,), cache_len=256)
    rep2 = planner.last_report
    assert rep2.solved == 0 and rep2.hit_rate == 1.0
    # cached plans bit-exactly reproduce the solver's objective
    assert [e.objective for e in man2.entries] == \
           [e.objective for e in man1.entries]
    assert man2.weighted_objective() == man1.weighted_objective()


def test_warm_start_keeps_zero_gap(tmp_path):
    store = PlanStore(tmp_path)
    cached_solve(Gemm(64, 128, 64), TEMPLATES["eyeriss-like"], store=store)
    res = cached_solve(Gemm(128, 128, 64), TEMPLATES["eyeriss-like"],
                       store=store, warm_start=True)
    cert = res.certificate
    assert cert.warm_started and cert.feasible
    assert cert.upper_bound == cert.lower_bound       # zero-gap certificate
    cold = solve(Gemm(128, 128, 64), TEMPLATES["eyeriss-like"])
    assert cold.certificate.objective == cert.objective
    assert cold.mapping == res.mapping


def test_incumbent_over_pruning_falls_back():
    """An incumbent at/below the optimum must never change the answer."""
    gemm, hw = Gemm(8, 8, 8), HW
    cold = solve(gemm, hw)
    for frac in (0.5, 1.0):
        res = solve(gemm, hw, incumbent=cold.certificate.objective * frac)
        assert res.certificate.objective == cold.certificate.objective
        assert res.mapping == cold.mapping


def test_manifest_round_trip(tmp_path):
    store = PlanStore(tmp_path / "db")
    man = BatchPlanner(store, jobs=1).plan_model(
        TINY, HW, prefill_seqs=(64,))
    path = man.save(tmp_path / "m.json")
    man2 = ModelMappingManifest.load(path)
    assert man2.model == man.model and man2.hw_name == man.hw_name
    assert man2.entries == man.entries
    assert man2.weighted_objective() == man.weighted_objective()
    assert man2.lookup(man.entries[0].dims) == man.entries[0]


def test_manifest_driven_goma_matmul(tmp_path):
    """Store-driven TpuTilePlan reconstruction feeds goma_matmul with zero
    solver invocations; result equals the jnp reference."""
    import jax
    import numpy as np
    from repro.kernels.ops import gemm as gemm_op
    from repro.kernels.ref import matmul_ref
    from repro.planner.batch import prewarm_tpu_plans, tile_plan_from_store

    from repro.core import tpu_mapping
    store = PlanStore(tmp_path)
    M, N, K = 300, 200, 100
    try:
        prewarm_tpu_plans([(M, N, K)], store)
    finally:
        tpu_mapping.set_plan_store(None)    # prewarm leaves it installed
    plan = tile_plan_from_store(store, M, N, K)
    assert store.puts > 0
    a = jax.random.normal(jax.random.PRNGKey(0), (M, K)) * 0.1
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N)) * 0.1
    out = gemm_op(a, b, interpret=True, plan=plan)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(matmul_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


def test_tpu_read_through(tmp_path):
    """plan_gemm_tiling consults an installed store instead of solving."""
    from repro.core import tpu_mapping
    store = PlanStore(tmp_path)
    prev = tpu_mapping.get_plan_store()
    tpu_mapping.set_plan_store(store)
    try:
        p1 = tpu_mapping.plan_gemm_tiling(256, 512, 128)
        assert store.puts >= 1
        # drop the in-process cache; the db must satisfy the re-plan
        tpu_mapping.plan_gemm_tiling.cache_clear()
        puts_before, hits_before = store.puts, store.hits
        p2 = tpu_mapping.plan_gemm_tiling(256, 512, 128)
        assert store.puts == puts_before        # no new solve
        assert store.hits > hits_before         # served from the db
        assert p2.block == p1.block and p2.grid_order == p1.grid_order
        assert p2.objective == p1.objective
    finally:
        tpu_mapping.set_plan_store(prev)


def test_prewarm_keeps_store_and_cache_installed(tmp_path):
    """Regression: prewarming must not flush the plan cache it built nor
    uninstall the store (the serving loop then consumes cached plans)."""
    from repro.core import tpu_mapping
    store = PlanStore(tmp_path)
    from repro.planner.batch import prewarm_tpu_plans
    try:
        prewarm_tpu_plans([(256, 512, 128)], store)
        assert tpu_mapping.get_plan_store() is store
        assert tpu_mapping.plan_gemm_tiling.cache_info().currsize >= 1
        puts = store.puts
        tpu_mapping.plan_gemm_tiling(256, 512, 128)   # lru, no new solve
        assert store.puts == puts
    finally:
        tpu_mapping.set_plan_store(None)


def test_corrupt_entry_is_a_miss(tmp_path):
    store = PlanStore(tmp_path)
    gemm = Gemm(8, 8, 8)
    res = cached_solve(gemm, HW, store=store)
    key = plan_key(gemm, HW)
    path = store._path(key.digest)
    path.write_text("{not json")
    store2 = PlanStore(tmp_path)
    assert store2.get(key) is None              # treated as miss, no raise
    res2 = cached_solve(gemm, HW, store=store2)  # heals the entry
    assert res2.certificate.objective == res.certificate.objective
    assert PlanStore(tmp_path).get(key) is not None


def test_cli_build_inspect_verify(tmp_path, capsys):
    from repro.planner.cli import main
    db = str(tmp_path / "db")
    rc = main(["build", "--model", "llama-3.2-1b", "--hw", "gemmini-like",
               "--seqs", "64", "--store", db,
               "--manifest", str(tmp_path / "m.json"), "--jobs", "1"])
    assert rc == 0
    out1 = capsys.readouterr().out
    assert "hit_rate=0%" in out1
    rc = main(["build", "--model", "llama-3.2-1b", "--hw", "gemmini-like",
               "--seqs", "64", "--store", db, "--jobs", "1"])
    assert rc == 0
    assert "hit_rate=100%" in capsys.readouterr().out
    assert main(["inspect", "--store", db, "-v"]) == 0
    assert main(["verify", "--store", db]) == 0
    capsys.readouterr()
    man = ModelMappingManifest.load(tmp_path / "m.json")
    assert len(man.entries) > 0
    data = json.loads((tmp_path / "m.json").read_text())
    assert data["schema_version"] == 1


def test_scenario_gemms_dedup_shape():
    rows = scenario_gemms(TINY, prefill_seqs=(64, 128),
                          decode_batches=(4,), cache_len=256)
    # 8 gemm types per phase, but identical (Gemm, name) rows merge with
    # summed weights: the seq-independent lm_head appears once for the
    # whole prefill sweep
    assert len(rows) == 3 * 8 - 1
    lm = [(g, w) for t, g, w in rows if t == "lm_head" and g.Lx == 1]
    assert len(lm) == 1 and lm[0][1] == 2     # weight 1 per prefill seq
