"""Hypothesis property tests on the system's core invariants.

``hypothesis`` is an optional dev dependency: when absent the module
skips instead of failing collection.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (EYERISS_LIKE, Gemm, Mapping, analytical_counts,
                        analytical_energy, closed_form_is_exact,
                        reference_counts, simulate_counts)
from repro.core.energy import rho_terms
from repro.core.fusion import mlp_chain
from repro.core.geometry import AXES, canonical_walk, divisor_chains


@st.composite
def gemm_and_mapping(draw, max_dim=16):
    dims = tuple(draw(st.integers(1, max_dim)) for _ in range(3))
    gemm = Gemm(*dims)
    chains = tuple(
        draw(st.sampled_from(divisor_chains(d))) for d in dims)
    m = Mapping(
        L1=tuple(c[0] for c in chains),
        L2=tuple(c[1] for c in chains),
        L3=tuple(c[2] for c in chains),
        alpha01=draw(st.sampled_from(AXES)),
        alpha12=draw(st.sampled_from(AXES)),
        res1=tuple(draw(st.booleans()) for _ in range(3)),
        res3=tuple(draw(st.booleans()) for _ in range(3)))
    return gemm, m


@settings(max_examples=150, deadline=None)
@given(gemm_and_mapping())
def test_counts_nonnegative_and_energy_positive(gm):
    gemm, m = gm
    counts = analytical_counts(gemm, m)
    for k, v in counts.as_dict().items():
        assert v >= -1e-9, (k, v, gemm, m)
    assert counts.energy(EYERISS_LIKE) > 0
    assert analytical_energy(gemm, m, EYERISS_LIKE).normalized > 0


@settings(max_examples=150, deadline=None)
@given(gemm_and_mapping())
def test_rho_in_unit_interval(gm):
    gemm, m = gm
    rho = rho_terms(gemm, m)
    for k in ("src1", "src3", "src4"):
        assert 0.0 <= rho[k] < 1.0, (k, rho[k])


@settings(max_examples=60, deadline=None)
@given(gemm_and_mapping(max_dim=10))
def test_reference_equals_simulator(gm):
    """Ground-truth invariant: loop-nest analysis == literal execution."""
    gemm, m = gm
    assert reference_counts(gemm, m, full_reuse=True).isclose(
        simulate_counts(gemm, m))


@settings(max_examples=60, deadline=None)
@given(gemm_and_mapping(max_dim=10))
def test_closed_form_upper_bounds_true_cost(gm):
    gemm, m = gm
    e_cf = analytical_counts(gemm, m).energy(EYERISS_LIKE)
    e_true = simulate_counts(gemm, m).energy(EYERISS_LIKE)
    assert e_cf >= e_true * (1 - 1e-9)


@settings(max_examples=60, deadline=None)
@given(gemm_and_mapping(max_dim=10))
def test_canonicalization_invariance(gm):
    """Aliased encodings execute identically (oracle counts equal)."""
    gemm, m = gm
    c = canonical_walk(gemm, m)
    assert simulate_counts(gemm, m).isclose(simulate_counts(gemm, c))


@settings(max_examples=40, deadline=None)
@given(gemm_and_mapping(max_dim=12), st.integers(0, 2))
def test_macc_count_equals_volume(gm, _):
    gemm, m = gm
    assert analytical_counts(gemm, m).macc == gemm.volume
    assert simulate_counts(gemm, m).macc == gemm.volume


# ---------------------------------------------------------------------------
# three-way model equality on random feasible mappings (chain links too)
# ---------------------------------------------------------------------------

def _draw_mapping(draw, gemm, *, pin_l1=None, pin_res1=None):
    """A divisibility-valid random mapping; optional L1 pins / forced
    res1 bits reproduce the chain solver's compatibility constraints."""
    chains = []
    for d in range(3):
        opts = divisor_chains(gemm.dims[d])
        if pin_l1 is not None and pin_l1[d] is not None:
            opts = tuple(c for c in opts if c[0] == pin_l1[d])
        chains.append(draw(st.sampled_from(opts)))
    res1 = tuple(
        True if (pin_res1 is not None and pin_res1[d])
        else draw(st.booleans()) for d in range(3))
    return Mapping(
        L1=tuple(c[0] for c in chains), L2=tuple(c[1] for c in chains),
        L3=tuple(c[2] for c in chains),
        alpha01=draw(st.sampled_from(AXES)),
        alpha12=draw(st.sampled_from(AXES)),
        res1=res1,
        res3=tuple(draw(st.booleans()) for _ in range(3)))


@st.composite
def chain_link_and_mapping(draw):
    """A random mapping of a random MLP-chain link — producer, consumer,
    or the same links under the chain solver's residency pins (the
    'chain intermediate' mappings the fused objective prices)."""
    m_rows = draw(st.sampled_from([2, 4, 6, 8]))
    ff = draw(st.sampled_from([4, 6, 8, 12]))
    d_model = draw(st.sampled_from([2, 4, 6, 9]))
    chain = mlp_chain(m_rows, ff, d_model)
    kind = draw(st.sampled_from(
        ["producer", "consumer", "producer_pinned", "consumer_pinned"]))
    gemm = chain.producer if kind.startswith("producer") else chain.consumer
    if kind == "producer_pinned":
        bm = draw(st.sampled_from(
            [c[0] for c in divisor_chains(chain.M)]))
        m = _draw_mapping(draw, gemm, pin_l1=(bm, chain.inter_width, None),
                          pin_res1=(False, False, True))
    elif kind == "consumer_pinned":
        bm = draw(st.sampled_from(
            [c[0] for c in divisor_chains(chain.M)]))
        m = _draw_mapping(draw, gemm, pin_l1=(bm, None, chain.inter_width),
                          pin_res1=(False, True, False))
    else:
        m = _draw_mapping(draw, gemm)
    return gemm, m


@settings(max_examples=120, deadline=None)
@given(chain_link_and_mapping())
def test_three_way_counts_on_chain_links(gm):
    """analytical == no-reuse reference (identity), full-reuse reference
    == simulator (ground truth), analytical == simulator whenever the
    exactness predicate holds — on random feasible mappings over chain
    link GEMMs, including the residency-pinned mappings the chain solver
    searches (replaces the fixed-case-only coverage)."""
    gemm, m = gm
    m.validate(gemm)
    cf = analytical_counts(gemm, m)
    assert cf.isclose(reference_counts(gemm, m, full_reuse=False)), (gemm, m)
    full = reference_counts(gemm, m, full_reuse=True)
    sim = simulate_counts(gemm, m)
    assert full.isclose(sim), (gemm, m)
    if closed_form_is_exact(gemm, m):
        assert cf.isclose(sim), (gemm, m)
