"""Hypothesis property tests on the system's core invariants.

``hypothesis`` is an optional dev dependency: when absent the module
skips instead of failing collection.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (EYERISS_LIKE, Gemm, Mapping, analytical_counts,
                        analytical_energy, reference_counts,
                        simulate_counts)
from repro.core.energy import rho_terms
from repro.core.geometry import AXES, canonical_walk, divisor_chains


@st.composite
def gemm_and_mapping(draw, max_dim=16):
    dims = tuple(draw(st.integers(1, max_dim)) for _ in range(3))
    gemm = Gemm(*dims)
    chains = tuple(
        draw(st.sampled_from(divisor_chains(d))) for d in dims)
    m = Mapping(
        L1=tuple(c[0] for c in chains),
        L2=tuple(c[1] for c in chains),
        L3=tuple(c[2] for c in chains),
        alpha01=draw(st.sampled_from(AXES)),
        alpha12=draw(st.sampled_from(AXES)),
        res1=tuple(draw(st.booleans()) for _ in range(3)),
        res3=tuple(draw(st.booleans()) for _ in range(3)))
    return gemm, m


@settings(max_examples=150, deadline=None)
@given(gemm_and_mapping())
def test_counts_nonnegative_and_energy_positive(gm):
    gemm, m = gm
    counts = analytical_counts(gemm, m)
    for k, v in counts.as_dict().items():
        assert v >= -1e-9, (k, v, gemm, m)
    assert counts.energy(EYERISS_LIKE) > 0
    assert analytical_energy(gemm, m, EYERISS_LIKE).normalized > 0


@settings(max_examples=150, deadline=None)
@given(gemm_and_mapping())
def test_rho_in_unit_interval(gm):
    gemm, m = gm
    rho = rho_terms(gemm, m)
    for k in ("src1", "src3", "src4"):
        assert 0.0 <= rho[k] < 1.0, (k, rho[k])


@settings(max_examples=60, deadline=None)
@given(gemm_and_mapping(max_dim=10))
def test_reference_equals_simulator(gm):
    """Ground-truth invariant: loop-nest analysis == literal execution."""
    gemm, m = gm
    assert reference_counts(gemm, m, full_reuse=True).isclose(
        simulate_counts(gemm, m))


@settings(max_examples=60, deadline=None)
@given(gemm_and_mapping(max_dim=10))
def test_closed_form_upper_bounds_true_cost(gm):
    gemm, m = gm
    e_cf = analytical_counts(gemm, m).energy(EYERISS_LIKE)
    e_true = simulate_counts(gemm, m).energy(EYERISS_LIKE)
    assert e_cf >= e_true * (1 - 1e-9)


@settings(max_examples=60, deadline=None)
@given(gemm_and_mapping(max_dim=10))
def test_canonicalization_invariance(gm):
    """Aliased encodings execute identically (oracle counts equal)."""
    gemm, m = gm
    c = canonical_walk(gemm, m)
    assert simulate_counts(gemm, m).isclose(simulate_counts(gemm, c))


@settings(max_examples=40, deadline=None)
@given(gemm_and_mapping(max_dim=12), st.integers(0, 2))
def test_macc_count_equals_volume(gm, _):
    gemm, m = gm
    assert analytical_counts(gemm, m).macc == gemm.volume
    assert simulate_counts(gemm, m).macc == gemm.volume
