"""Serving scale-out (DESIGN.md §Scale-out): replica router, KV prefix
cache, speculative decoding.

The invariants under test:

  * **bit-identity everywhere** — prefix grafting, speculative
    verification (static and scheduler paths), and multi-replica
    routing all emit exactly the tokens the static ``Engine.generate``
    oracle emits; the optimizations change cost, never content,
  * **zero-solve fleet** — one prewarm pass on the donor replica
    certifies zero steady-state solver invocations across all replicas
    (spec verify windows included),
  * **clear degradation** — unsupported families fail construction
    with a named error and the router degrades to the static path;
    the prefix cache evicts under byte pressure without losing
    correctness.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import tpu_mapping
from repro.core.solver import reset_solver_stats, solver_stats
from repro.models import build_model
from repro.obs.registry import get_registry
from repro.planner import PlanStore
from repro.serving import Engine, ServeConfig
from repro.serving.sched import (SUPPORTED_FAMILIES, ContinuousScheduler,
                                 Request, SchedConfig, ServingMetrics,
                                 TrafficConfig, ensure_supported_family,
                                 shared_prefix_trace)
from repro.serving.router import (ModelDrafter, NgramDrafter, PrefixCache,
                                  ReplicaRouter, RouterConfig,
                                  spec_generate)

CACHE = 128


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3-8b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(max_new_tokens=10,
                                               cache_len=CACHE))
    oracle = Engine(model, params, ServeConfig(max_new_tokens=10,
                                               cache_len=CACHE))
    return cfg, model, params, engine, oracle


def _oracle_tokens(oracle: Engine, req: Request) -> list[int]:
    oracle.cfg.max_new_tokens = req.max_new_tokens
    oracle.cfg.stop_token = req.stop_token
    row = oracle.generate(req.tokens[None])[0]
    out = []
    for t in row[:req.max_new_tokens]:
        out.append(int(t))
        if req.stop_token is not None and int(t) == req.stop_token:
            break
    return out


def _assert_oracle_identical(results, reqs, oracle):
    by_id = {r.req_id: r for r in results}
    assert sorted(by_id) == sorted(r.req_id for r in reqs)
    for req in reqs:
        assert by_id[req.req_id].tokens == _oracle_tokens(oracle, req), \
            req.req_id


def _shared_prefix_requests(cfg, *, n=6, prefix_len=32, tail=5,
                            max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, (prefix_len,)).astype(np.int32)
    reqs = []
    for i in range(n):
        t = rng.integers(0, cfg.vocab, (tail,)).astype(np.int32)
        reqs.append(Request(
            req_id=i, tokens=np.concatenate([shared, t]),
            max_new_tokens=max_new, arrival_s=0.001 * i))
    return reqs


# ------------------------------------------------------- prefix cache

def test_prefix_cache_units(setup):
    """Boundary quantization, exact-token hit/miss, LRU byte budget."""
    _, _, _, engine, _ = setup
    pc = PrefixCache(16, max_bytes=1 << 20)
    assert pc._boundary(17) == 16
    assert pc._boundary(16) == 0       # P <= prompt_len - 1 always
    assert pc._boundary(33) == 32
    toks = np.arange(40, dtype=np.int32)
    cache = engine.new_cache(1)
    assert pc.lookup(toks) is None                 # cold
    assert pc.insert(toks, cache)                  # stores P=32
    p, entry = pc.lookup(toks)
    assert p == 32 and entry.p == 32
    # same boundary, different tokens: no hit (exact-token guard)
    other = toks.copy()
    other[3] += 1
    assert pc.lookup(other) is None
    # shorter prompt sharing the 16-boundary prefix hits at P=16...
    # only if a P=16 entry exists — the P=32 entry does not serve it
    assert pc.lookup(toks[:20]) is None
    assert pc.insert(toks[:20], cache)
    p2, _ = pc.lookup(toks[:20])
    assert p2 == 16
    # prompts too short to quantize never store
    assert not pc.insert(toks[:9], cache)


def test_prefix_cache_lru_eviction_under_byte_pressure(setup):
    _, _, _, engine, _ = setup
    cache = engine.new_cache(1)
    one = jax.tree.leaves(jax.tree.map(
        lambda a: np.asarray(a[:, :, :16]), cache))
    entry_bytes = sum(leaf.nbytes for leaf in one)
    pc = PrefixCache(16, max_bytes=2 * entry_bytes)   # room for two
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 200, (20,)).astype(np.int32)
               for _ in range(3)]
    for p in prompts:
        assert pc.insert(p, cache)
    assert len(pc) == 2                               # oldest evicted
    assert pc.lookup(prompts[0]) is None
    assert pc.lookup(prompts[1]) is not None
    assert pc.lookup(prompts[2]) is not None
    snap = get_registry().snapshot()
    assert snap["prefix.evictions"] == 1
    assert pc.bytes_used <= pc.max_bytes


def test_prefix_serving_bit_identical_and_saves_prefill(setup):
    """Shared-prefix trace with the cache on: fewer prefill chunks,
    prefix.* traffic counted, tokens bit-identical to the oracle."""
    cfg, _, _, engine, oracle = setup
    reqs = _shared_prefix_requests(cfg)
    base = ContinuousScheduler(
        engine, SchedConfig(slots=2, chunk_widths=(8, 16)))
    base_results = base.run([Request(
        req_id=r.req_id, tokens=r.tokens,
        max_new_tokens=r.max_new_tokens) for r in reqs])
    _assert_oracle_identical(base_results, reqs, oracle)
    chunks_without = base.metrics.prefill_chunks

    get_registry().reset()
    pc = PrefixCache(16)
    sched = ContinuousScheduler(
        engine, SchedConfig(slots=2, chunk_widths=(8, 16)),
        prefix_cache=pc)
    results = sched.run(reqs)
    _assert_oracle_identical(results, reqs, oracle)
    assert sched.metrics.prefill_chunks < chunks_without
    snap = get_registry().snapshot()
    assert snap["prefix.hits"] >= len(reqs) - 1    # all but the first
    assert snap["sched.prefix_tokens_reused"] >= 32 * (len(reqs) - 1)


def test_prefix_eviction_during_serving_keeps_identity(setup):
    """A byte budget too small to hold every prefix thrashes the cache
    but never corrupts a stream."""
    cfg, _, _, engine, oracle = setup
    rng = np.random.default_rng(5)
    reqs = []
    for i in range(6):       # three distinct prefixes, interleaved
        shared = rng.integers(0, cfg.vocab, (32,)).astype(np.int32)
        for j in range(2):
            tail = rng.integers(0, cfg.vocab, (4,)).astype(np.int32)
            reqs.append(Request(
                req_id=10 * i + j,
                tokens=np.concatenate([shared, tail]),
                max_new_tokens=5))
    cache = engine.new_cache(1)
    entry_bytes = sum(leaf.nbytes for leaf in jax.tree.leaves(
        jax.tree.map(lambda a: np.asarray(a[:, :, :32]), cache)))
    pc = PrefixCache(16, max_bytes=entry_bytes + entry_bytes // 2)
    sched = ContinuousScheduler(
        engine, SchedConfig(slots=2, chunk_widths=(8, 16)),
        prefix_cache=pc)
    results = sched.run(reqs)
    _assert_oracle_identical(results, reqs, oracle)
    assert get_registry().get("prefix.evictions") > 0


# ------------------------------------------------ speculative decoding

def test_spec_generate_ngram_byte_identical(setup):
    cfg, _, _, engine, oracle = setup
    rng = np.random.default_rng(0)
    for seed in range(3):
        prompt = np.random.default_rng(seed).integers(
            0, cfg.vocab, (11 + seed,)).astype(np.int32)
        oracle.cfg.max_new_tokens = 20
        oracle.cfg.stop_token = None
        want = [int(t) for t in oracle.generate(prompt[None])[0]]
        got = spec_generate(engine, prompt, NgramDrafter(),
                            max_new_tokens=20)
        assert list(got) == want
    assert get_registry().get("spec.rounds") > 0


def test_spec_generate_stop_token_identical(setup):
    """Stop tokens hit mid-verify-window truncate identically."""
    cfg, _, _, engine, oracle = setup
    # pick the stop token off the oracle's own stream so it fires
    # early; the first occurrence is the delivery boundary either way
    for seed in range(10):
        prompt = np.random.default_rng(seed).integers(
            0, cfg.vocab, (10,)).astype(np.int32)
        oracle.cfg.max_new_tokens = 16
        oracle.cfg.stop_token = None
        row = [int(t) for t in oracle.generate(prompt[None])[0]]
        stop = row[len(row) // 2]
        want = row[:row.index(stop) + 1]
        if len(want) == len(row):
            continue                     # stop would not fire early
        got = spec_generate(engine, prompt, NgramDrafter(),
                            max_new_tokens=16, stop_token=stop)
        assert list(got) == want
        return
    pytest.skip("no early-stopping prompt found")


def test_spec_generate_model_drafter_byte_identical(setup):
    """A draft model (different init => different predictions) through
    the same capture-served engine: still byte-identical — drafters
    set throughput, never content."""
    cfg, model, _, engine, oracle = setup
    dparams = model.init_params(jax.random.PRNGKey(9))
    draft = Engine(model, dparams, ServeConfig(cache_len=CACHE))
    prompt = np.random.default_rng(2).integers(
        0, cfg.vocab, (12,)).astype(np.int32)
    oracle.cfg.max_new_tokens = 16
    oracle.cfg.stop_token = None
    want = [int(t) for t in oracle.generate(prompt[None])[0]]
    got = spec_generate(engine, prompt, ModelDrafter(draft),
                        max_new_tokens=16)
    assert list(got) == want
    assert get_registry().get("spec.draft_steps") > 0


def test_scheduler_spec_decoding_token_identical(setup):
    cfg, _, _, engine, oracle = setup
    rng = np.random.default_rng(4)
    reqs = [Request(req_id=i,
                    tokens=rng.integers(0, cfg.vocab,
                                        (9 + i,)).astype(np.int32),
                    max_new_tokens=10) for i in range(5)]
    sched = ContinuousScheduler(
        engine, SchedConfig(slots=3, chunk_widths=(8, 16), spec_width=4),
        drafter=NgramDrafter())
    results = sched.run(reqs)
    _assert_oracle_identical(results, reqs, oracle)
    snap = get_registry().snapshot()
    assert snap["sched.spec.rounds"] > 0
    assert snap["sched.spec.drafted"] == 3 * snap["sched.spec.rounds"]


def test_spec_config_validation(setup):
    _, _, _, engine, _ = setup
    with pytest.raises(ValueError, match="greedy"):
        ContinuousScheduler(
            engine, SchedConfig(slots=2, temperature=0.7, spec_width=4),
            drafter=NgramDrafter())
    with pytest.raises(ValueError, match="spec_width"):
        ContinuousScheduler(
            engine, SchedConfig(slots=2), drafter=NgramDrafter())
    with pytest.raises(ValueError, match="cache positions"):
        # lookahead headroom: prompt + budget alone fit, + window not
        engine.validate_capacity(CACHE - 12, 12, lookahead=3)


# --------------------------------------------------------------- router

def test_router_oracle_identity_and_load_spread(setup):
    cfg, _, _, engine, oracle = setup
    rng = np.random.default_rng(6)
    reqs = [Request(req_id=i,
                    tokens=rng.integers(0, cfg.vocab,
                                        (8 + i % 7,)).astype(np.int32),
                    max_new_tokens=6, arrival_s=0.0005 * i)
            for i in range(10)]
    router = ReplicaRouter(
        engine, RouterConfig(replicas=2, sched=SchedConfig(
            slots=2, chunk_widths=(8, 16))))
    results = router.route_trace(reqs)
    _assert_oracle_identical(results, reqs, oracle)
    snap = get_registry().snapshot()
    assert snap["router.routed"] == len(reqs)
    assert snap["router.replica0.routed"] > 0      # both replicas
    assert snap["router.replica1.routed"] > 0      # carried load
    assert router.summary()["requests"] == len(reqs)


def test_router_fleet_zero_solver_invocations(setup, tmp_path):
    """One donor prewarm pass covers the fleet: replicas 1..N-1 skip
    planning entirely, yet steady-state traffic (chunk prefill, prefix
    grafts, spec verify windows) makes zero solver invocations."""
    cfg, model, params, _, oracle = setup
    store = PlanStore(tmp_path)
    engine = Engine(model, params,
                    ServeConfig(max_new_tokens=10, cache_len=CACHE),
                    plan_store=store)
    try:
        router = ReplicaRouter(
            engine, RouterConfig(replicas=3, sched=SchedConfig(
                slots=2, chunk_widths=(4, 16), spec_width=4)),
            prefix_cache=PrefixCache(16), drafter=NgramDrafter())
        assert router.prewarmed_plans > 0
        assert store.puts > 0
        for s in router.scheds[1:]:
            assert s.prewarmed_plans == 0          # donor's pass reused
            assert "verify4" in s._plan_groups
        misses0 = store.misses
        reset_solver_stats()
        reqs = _shared_prefix_requests(cfg, n=8, prefix_len=16,
                                       max_new=5, seed=7)
        results = router.route_trace(reqs)
        assert solver_stats()["calls"] == 0        # fleet-wide cert
        assert store.misses == misses0
        _assert_oracle_identical(results, reqs, oracle)
    finally:
        engine.plan_store = None
        tpu_mapping.set_plan_store(None)
        tpu_mapping.plan_gemm_tiling.cache_clear()


def test_unsupported_family_error_and_static_fallback():
    cfg = get_config("rwkv6-7b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(max_new_tokens=5,
                                               cache_len=64))
    # the construction-time error names the supported families
    with pytest.raises(ValueError) as ei:
        ensure_supported_family(model.cfg)
    assert str(SUPPORTED_FAMILIES) in str(ei.value)
    with pytest.raises(ValueError, match="continuous batching supports"):
        ContinuousScheduler(engine, SchedConfig(slots=2))
    # the router degrades to Engine.generate instead of raising
    router = ReplicaRouter(engine, RouterConfig(replicas=2))
    assert router.static_reason is not None
    rng = np.random.default_rng(0)
    reqs = [Request(req_id=i,
                    tokens=rng.integers(0, cfg.vocab,
                                        (10,)).astype(np.int32),
                    max_new_tokens=5, arrival_s=0.001 * i)
            for i in range(3)]
    results = router.route_trace(reqs)
    assert len(results) == len(reqs)
    assert all(len(r.tokens) == 5 and r.finish_reason == "length"
               for r in results)
    assert "static_fallback" in router.summary()
    assert get_registry().get("router.static_fallback") == 1


# ---------------------------------------------------------- SLO metrics

def _result(req_id, *, arrival=0.0, first=0.1, finish=1.0, n=10,
            reason="length"):
    from repro.serving.sched import RequestResult
    return RequestResult(
        req_id=req_id, tokens=list(range(n)), finish_reason=reason,
        prompt_len=8, arrival_s=arrival, first_token_s=first,
        finish_s=finish)


def test_slo_attainment_and_goodput():
    m = ServingMetrics(ttft_slo_s=0.5, tpot_slo_s=0.2)
    m.started_s, m.finished_s = 0.0, 2.0
    m.record_result(_result(0, first=0.1, finish=1.0, n=10))   # attains
    m.record_result(_result(1, first=0.9, finish=1.5, n=10))   # ttft miss
    m.record_result(_result(2, first=0.2, finish=3.0, n=10))   # tpot miss
    s = m.summary()
    assert s["slo_attainment"] == pytest.approx(1 / 3, abs=1e-4)
    assert s["goodput_tokens_per_s"] == pytest.approx(10 / 2.0)
    assert s["tokens_per_s"] == pytest.approx(30 / 2.0)


def test_slo_nan_and_empty_are_safe():
    # shed request (NaN first token) never attains, never crashes
    m = ServingMetrics(ttft_slo_s=0.5)
    m.started_s, m.finished_s = 0.0, 1.0
    m.record_result(_result(0, first=float("nan"), n=0,
                            reason="rejected"))
    s = m.summary()
    assert s["slo_attainment"] == 0.0
    assert s["goodput_tokens_per_s"] == 0.0
    # no SLO configured -> no SLO keys (summary unchanged)
    assert "slo_attainment" not in ServingMetrics().summary()


def test_merged_metrics_use_makespan():
    a = ServingMetrics()
    a.started_s, a.finished_s = 0.0, 2.0
    a.record_result(_result(0, n=4))
    b = ServingMetrics()
    b.started_s, b.finished_s = 0.0, 5.0
    b.record_result(_result(1, n=6))
    m = ServingMetrics.merged([a, b])
    assert m.elapsed_s == pytest.approx(5.0)       # slowest part
    assert m.total_generated == 10
    m2 = ServingMetrics.merged([a, b], elapsed_s=7.0)
    assert m2.elapsed_s == pytest.approx(7.0)
