"""Continuous-batching scheduler: token-identical to the static oracle
under arbitrary arrival schedules, zero solver invocations in steady
state with a plan store installed, bucket/slot unit semantics, and the
engine satellites (per-step rng split, capacity validation)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.solver import reset_solver_stats, solver_stats
from repro.models import build_model
from repro.planner import PlanStore
from repro.serving import Engine, ServeConfig
from repro.serving.sched import (BucketSpec, ContinuousScheduler, Request,
                                 SchedConfig, SlotManager, TraceClock,
                                 TrafficConfig, poisson_trace, replay)

CACHE = 96


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3-8b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(max_new_tokens=10,
                                               cache_len=CACHE))
    # one shared oracle engine: cfg is mutated per request (the jitted
    # prefill/decode only close over cache_len)
    oracle = Engine(model, params, ServeConfig(max_new_tokens=10,
                                               cache_len=CACHE))
    return cfg, model, params, engine, oracle


def _oracle_tokens(oracle: Engine, req: Request) -> list[int]:
    """The request alone through static Engine.generate, trimmed to the
    delivered sequence (up to and including the first stop token)."""
    oracle.cfg.max_new_tokens = req.max_new_tokens
    oracle.cfg.stop_token = req.stop_token
    row = oracle.generate(req.tokens[None])[0]
    out = []
    for t in row[:req.max_new_tokens]:
        out.append(int(t))
        if req.stop_token is not None and int(t) == req.stop_token:
            break
    return out


def _check_against_oracle(results, reqs, oracle):
    by_id = {r.req_id: r for r in results}
    assert sorted(by_id) == sorted(r.req_id for r in reqs)
    for req in reqs:
        res = by_id[req.req_id]
        want = _oracle_tokens(oracle, req)
        assert res.tokens == want, (req.req_id, res.tokens, want)
        if res.finish_reason == "stop":
            assert res.tokens[-1] == req.stop_token
        else:
            assert len(res.tokens) == req.max_new_tokens


# ---------------------------------------------------------------- units

def test_bucket_quantization():
    spec = BucketSpec((4, 16))
    for L in (1, 3, 4, 5, 15, 16, 17, 33, 64):
        chunks = spec.plan_chunks(L)
        assert sum(c.n_real for c in chunks) == L
        assert all(c.width in (4, 16) for c in chunks)
        # contiguous, and only the final chunk may be padded
        pos = 0
        for c in chunks:
            assert c.start == pos
            pos += c.n_real
        assert all(not c.is_padded for c in chunks[:-1])
        assert spec.padded_len(L) >= L
        assert spec.padded_len(L) - L < 16    # waste < largest bucket
    # the jit/plan-key bound: distinct widths only, traffic-independent
    assert len({c.width for L in range(1, 100)
                for c in spec.plan_chunks(L)}) <= 2


def test_slot_free_list_recycling():
    sm = SlotManager(2)
    r = lambda i: Request(req_id=i, tokens=np.ones(3), max_new_tokens=2)
    a = sm.acquire(r(0))
    b = sm.acquire(r(1))
    assert {a.idx, b.idx} == {0, 1}
    assert sm.acquire(r(2)) is None          # pool exhausted
    sm.release(a)
    c = sm.acquire(r(3))
    assert c.idx == a.idx                    # LIFO recycling
    assert c.tokens == [] and c.emitted == 0   # state reset on acquire
    assert sm.n_busy == 2 and sm.n_free == 0


# --------------------------------------------- differential vs oracle

def test_smoke_staggered_arrivals_stop_token(setup):
    """The CI-lane smoke: 8 requests, staggered arrivals, stop token —
    outputs match the static-batch oracle row-for-row."""
    cfg, model, params, engine, oracle = setup
    rng = np.random.default_rng(0)
    stop = 7
    reqs = [Request(req_id=i,
                    tokens=rng.integers(0, cfg.vocab,
                                        (int(rng.integers(3, 24)),)),
                    max_new_tokens=10, arrival_s=0.02 * i,
                    stop_token=stop)
            for i in range(8)]
    clock = TraceClock()
    sched = ContinuousScheduler(
        engine, SchedConfig(slots=3, chunk_widths=(4, 16)),
        clock=clock.now)
    results = replay(sched, reqs, clock)
    _check_against_oracle(results, reqs, oracle)
    # slots were recycled (8 requests through 3 slots) and prefill was
    # genuinely chunked
    assert sched.metrics.prefill_chunks >= 8
    assert sched.metrics.summary()["mean_slot_occupancy"] > 0


@pytest.mark.slow
@pytest.mark.parametrize("schedule", ["burst", "trickle", "poisson"])
def test_arrival_schedules_match_oracle(setup, schedule):
    """Arbitrary arrival schedules with mixed prompt lengths and
    per-request budgets stay token-identical to the oracle."""
    cfg, model, params, engine, oracle = setup
    rng = np.random.default_rng({"burst": 1, "trickle": 2,
                                 "poisson": 3}[schedule])
    n = 10
    if schedule == "poisson":
        reqs = poisson_trace(TrafficConfig(
            n_requests=n, arrival_rate=30.0,
            prompt_mix=((3, 10, 0.6), (11, 40, 0.4)),
            max_new_range=(3, 10), vocab=cfg.vocab, seed=5))
    else:
        arrivals = ([0.0] * n if schedule == "burst"
                    else [0.3 * i for i in range(n)])
        reqs = [Request(req_id=i,
                        tokens=rng.integers(0, cfg.vocab,
                                            (int(rng.integers(3, 40)),)),
                        max_new_tokens=int(rng.integers(3, 11)),
                        arrival_s=arrivals[i])
                for i in range(n)]
    clock = TraceClock()
    sched = ContinuousScheduler(
        engine, SchedConfig(slots=3, chunk_widths=(4, 16),
                            prefill_chunks_per_step=2),
        clock=clock.now)
    results = replay(sched, reqs, clock)
    _check_against_oracle(results, reqs, oracle)


def test_streaming_callbacks_and_metrics(setup):
    cfg, model, params, engine, oracle = setup
    rng = np.random.default_rng(3)
    reqs = [Request(req_id=i, tokens=rng.integers(0, cfg.vocab, (5,)),
                    max_new_tokens=4) for i in range(2)]
    streamed: dict[int, list[int]] = {}
    finished = []
    clock = TraceClock()
    sched = ContinuousScheduler(
        engine, SchedConfig(slots=3, chunk_widths=(4, 16)),
        on_token=lambda req, tok: streamed.setdefault(req.req_id,
                                                      []).append(tok),
        on_finish=finished.append, clock=clock.now)
    results = replay(sched, reqs, clock)
    for res in results:
        assert streamed[res.req_id] == res.tokens   # streamed in order
        assert res.first_token_s <= res.finish_s
        # the pinned trace clock counts in-tick compute, so TTFT is
        # strictly positive (prefill work happened before the token)
        assert res.ttft_s > 0
    assert {f.req_id for f in finished} == {0, 1}
    summ = sched.metrics.summary()
    assert summ["requests"] == 2
    assert summ["total_generated_tokens"] == 8


def test_scheduler_rejects_recurrent_families():
    cfg = get_config("rwkv6-7b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(cache_len=32))
    with pytest.raises(ValueError, match="continuous batching supports"):
        ContinuousScheduler(engine, SchedConfig(slots=2))


# ------------------------------------------------- plan-DB integration

def test_zero_solver_invocations_steady_state(setup, tmp_path):
    """Scheduler construction prewarms every bucketed GEMM tiling
    through the PlanStore; steady-state traffic then resolves all tile
    plans with zero solver invocations and zero store misses."""
    from repro.core import tpu_mapping
    cfg, model, params, engine, oracle = setup
    store = PlanStore(tmp_path)
    engine.plan_store = store
    try:
        clock = TraceClock()
        sched = ContinuousScheduler(
            engine, SchedConfig(slots=3, chunk_widths=(4, 16)),
            arch_id="llama3-8b", clock=clock.now)
        assert sched.prewarmed_plans > 0
        assert store.puts > 0                 # fresh store was populated
        misses0 = store.misses
        reset_solver_stats()
        rng = np.random.default_rng(1)
        reqs = [Request(req_id=i,
                        tokens=rng.integers(0, cfg.vocab, (12,)),
                        max_new_tokens=4, arrival_s=0.0)
                for i in range(4)]
        replay(sched, reqs, clock)
        assert solver_stats()["calls"] == 0   # zero-solve steady state
        assert store.misses == misses0        # every lookup a hit
    finally:
        engine.plan_store = None
        tpu_mapping.set_plan_store(None)


def test_fused_mlp_scheduler_prewarms_chains(tmp_path):
    """A fused-MLP model's scheduler prewarms the bucketed fused chain
    plans (one per bucket group) alongside the per-GEMM tilings; steady
    state then runs with zero solver invocations — chain solves included
    — and stays token-identical to the static oracle of the same
    model."""
    import dataclasses
    from repro.core import tpu_mapping
    cfg = dataclasses.replace(get_config("llama3-8b", smoke=True),
                              fused_mlp=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = Engine(model, params,
                    ServeConfig(max_new_tokens=6, cache_len=CACHE))
    oracle = Engine(model, params,
                    ServeConfig(max_new_tokens=6, cache_len=CACHE))
    store = PlanStore(tmp_path)
    engine.plan_store = store
    try:
        clock = TraceClock()
        sched = ContinuousScheduler(
            engine, SchedConfig(slots=2, chunk_widths=(4, 16)),
            arch_id="llama3-8b", clock=clock.now)
        assert sched.prewarmed_chains > 0
        assert store.num_fused() > 0          # fused section populated
        reset_solver_stats()
        rng = np.random.default_rng(3)
        reqs = [Request(req_id=i,
                        tokens=rng.integers(0, cfg.vocab, (10,)),
                        max_new_tokens=4, arrival_s=0.0)
                for i in range(3)]
        results = replay(sched, reqs, clock)
        assert solver_stats()["calls"] == 0   # no GEMM or chain solves
        _check_against_oracle(results, reqs, oracle)
    finally:
        engine.plan_store = None
        tpu_mapping.set_plan_store(None)


def test_prewarm_dtype_mismatch_misses(setup, tmp_path, monkeypatch):
    """Plan identity includes the dtype-rescaled VMEM capacity: plans
    prewarmed under the wrong dtype_bytes miss at dispatch time; the
    engine's default (its compute dtype) hits."""
    from repro.capture import plan as capture_plan
    from repro.core import tpu_mapping
    cfg, model, params, engine, oracle = setup
    monkeypatch.setattr(capture_plan, "serving_capture_shapes",
                        lambda *a, **k: [(64, 64, 64)])
    store = PlanStore(tmp_path)
    engine.plan_store = store
    try:
        assert engine.dispatch_dtype_bytes == 4       # f32 smoke model
        # prewarm under bf16 capacity -> f32 dispatch must miss + solve
        engine.prewarm_plans("llama3-8b", 1, 8, dtype_bytes=2)
        tpu_mapping.plan_gemm_tiling.cache_clear()
        misses0, puts0 = store.misses, store.puts
        reset_solver_stats()
        tpu_mapping.plan_gemm_tiling(64, 64, 64, dtype_bytes=4)
        assert store.misses > misses0
        assert store.puts > puts0             # healed by a fresh solve
        assert solver_stats()["calls"] > 0
        # prewarm under the engine default -> dispatch hits, no solve
        engine.prewarm_plans("llama3-8b", 1, 8)
        tpu_mapping.plan_gemm_tiling.cache_clear()
        misses1, hits1 = store.misses, store.hits
        reset_solver_stats()
        tpu_mapping.plan_gemm_tiling(64, 64, 64, dtype_bytes=4)
        assert store.misses == misses1
        assert store.hits > hits1
        assert solver_stats()["calls"] == 0
    finally:
        engine.plan_store = None
        tpu_mapping.set_plan_store(None)


# ------------------------------------------------- engine satellites

def test_generate_rng_splits_per_step(setup):
    """Regression: temperature sampling must draw fresh Gumbel noise per
    decode step.  At temperature >> |logits| sampling is pure noise, so
    reusing one key would emit the same token every step."""
    cfg, model, params, engine, oracle = setup
    eng = Engine(model, params, ServeConfig(
        max_new_tokens=8, cache_len=CACHE, temperature=1e6))
    prompts = np.array([[1, 2, 3, 4]], np.int32)
    out = eng.generate(prompts, rng=jax.random.PRNGKey(0))
    assert len(set(out[0].tolist())) > 1, out
    # deterministic given the key
    out2 = eng.generate(prompts, rng=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(out, out2)


def test_capacity_validation(setup):
    cfg, model, params, engine, oracle = setup
    eng = Engine(model, params, ServeConfig(max_new_tokens=64,
                                            cache_len=CACHE))
    with pytest.raises(ValueError, match="cache_len"):
        eng.generate(np.ones((1, 40), np.int32))     # 40 + 64 > 96
    clock = TraceClock()
    sched = ContinuousScheduler(
        engine, SchedConfig(slots=3, chunk_widths=(4, 16), max_queue=1),
        clock=clock.now)
    with pytest.raises(ValueError, match="cache_len"):
        sched.submit(Request(req_id=0, tokens=np.ones(90),
                             max_new_tokens=10))
    sched.submit(Request(req_id=1, tokens=np.ones(4), max_new_tokens=2))
    with pytest.raises(RuntimeError, match="queue full"):   # admission
        sched.submit(Request(req_id=2, tokens=np.ones(4),
                             max_new_tokens=2))
    sched.run()                                     # drain for isolation
