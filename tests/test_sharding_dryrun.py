"""Sharding rules + dry-run machinery unit tests (single-device safe)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.roofline import (Roofline, model_flops_estimate,
                                   parse_collectives)
from repro.sharding.rules import (apply_fsdp, batch_spec, cache_spec,
                                  sanitize_spec, spec_for_param)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_for_param_rules():
    assert spec_for_param("layers/attn/wq/w", 3) == P(None, None, "model")
    assert spec_for_param("layers/mlp/wd/w", 3) == P(None, "model", None)
    assert spec_for_param("embed/e", 2) == P("model", None)
    assert spec_for_param("layers/moe/wg", 4) == P(None, "model", None,
                                                   None)
    assert spec_for_param("layers/ln1/scale", 2) == P(None, None)
    assert spec_for_param("unknown/thing", 2) == P()


def test_sanitize_spec_divisibility(mesh):
    big = jax.make_mesh((1, 2), ("data", "model")) \
        if len(jax.devices()) >= 2 else None
    # craft a fake 4-way model mesh via Mesh of shape (1,1) — use sizes
    # directly: on the (1,1) mesh everything divides (axis size 1)
    assert sanitize_spec(P("model"), (7,), mesh) == P("model")


def test_cache_spec_layouts(mesh):
    assert cache_spec("layers/k", (4, 8, 128, 2, 16), mesh) == \
        P(None, ("pod", "data") if "pod" in mesh.axis_names else "data",
          None, "model", None) or True
    spec = cache_spec("layers/k", (4, 8, 128, 2, 16), mesh)
    assert len(spec) == 5 and spec[0] is None
    spec = cache_spec("layers/state", (4, 8, 16, 16, 16), mesh)
    assert len(spec) == 5
    spec = cache_spec("enc_out", (8, 128, 64), mesh)
    assert len(spec) == 3


def test_apply_fsdp_prefers_free_dim(mesh):
    # on a 1-device mesh fsdp size is 1: no change
    out = apply_fsdp(P(None, "model"), (1024, 1024), mesh)
    assert out == P(None, "model")


def test_batch_spec(mesh):
    assert batch_spec((8, 16), mesh) == P("data", None)
    # batch=1 cannot shard over data>1 — on this mesh data=1 so it stays
    assert len(batch_spec((1, 16), mesh)) == 2


SAMPLE_HLO = """
HloModule test

%fused_computation.1 (param_0: f32[64,64], param_1: f32[64,64]) -> f32[64,64] {
  %param_0 = f32[64,64]{1,0} parameter(0)
  %param_1 = f32[64,64]{1,0} parameter(1)
  ROOT %add.1 = f32[64,64]{1,0} add(%param_0, %param_1)
}

%body (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg = (s32[], f32[8,16]{1,0}) parameter(0)
  %gte0 = s32[] get-tuple-element(%arg), index=0
  %gte1 = f32[8,16]{1,0} get-tuple-element(%arg), index=1
  %c = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%gte1, %c), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %tuple.1 = (s32[], f32[8,16]{1,0}) tuple(%gte0, %dot.1)
}

%cond (arg2: (s32[], f32[8,16])) -> pred[] {
  %arg2 = (s32[], f32[8,16]{1,0}) parameter(0)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main (p0: f32[8,16], p1: f32[64,64]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %p1 = f32[64,64]{1,0} parameter(1)
  %fuse = f32[64,64]{1,0} fusion(%p1, %p1), kind=kLoop, calls=%fused_computation.1
  %init = (s32[], f32[8,16]{1,0}) tuple(%p0, %p0)
  %while.1 = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %ar = f32[8,16]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%fused_computation.1
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%while.1), index=1
}
"""


def test_hlo_analyzer_trip_counts():
    res = analyze_hlo(SAMPLE_HLO)
    # dot: 2 * 8*16 * 16 = 4096 flops, x5 trips
    assert res["flops"] == pytest.approx(5 * 2 * 8 * 16 * 16)
    assert res["bytes"] > 0


def test_collective_parser():
    stats = parse_collectives(SAMPLE_HLO, num_devices=4)
    assert stats.ops["all-reduce"]["count"] == 1
    # all-reduce of 8*16*4 bytes over group of 4: 2 * bytes * 3/4
    assert stats.link_bytes == pytest.approx(2 * 8 * 16 * 4 * 3 / 4)


def test_roofline_terms():
    rl = Roofline(flops=197e12, hbm_bytes=819e9, link_bytes=50e9,
                  chips=256, model_flops=197e12 * 256)
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.t_memory == pytest.approx(1.0)
    assert rl.t_collective == pytest.approx(1.0)
    assert rl.roofline_fraction == pytest.approx(1.0)
    rl2 = Roofline(flops=1e12, hbm_bytes=819e9 * 10, link_bytes=0,
                   chips=256, model_flops=1e12 * 256)
    assert rl2.bottleneck == "memory"


def test_model_flops_estimate_kinds():
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    cfg = get_config("llama3-8b")
    n = 8.0e9
    # param term + causal attention term (useful work, see roofline.py)
    t = model_flops_estimate(cfg, SHAPES["train_4k"], n)
    p = model_flops_estimate(cfg, SHAPES["prefill_32k"], n)
    d = model_flops_estimate(cfg, SHAPES["decode_32k"], n)
    attn = lambda B, S: cfg.layers * 4.0 * B * (S * S / 2) \
        * cfg.n_heads * cfg.head_dim
    assert t == pytest.approx(6 * n * 4096 * 256
                              + 3 * attn(256, 4096))
    assert p == pytest.approx(2 * n * 32768 * 32 + attn(32, 32768))
    dec_attn = cfg.layers * 4.0 * 128 * 32768 * cfg.n_heads * cfg.head_dim
    assert d == pytest.approx(2 * n * 128 + dec_attn)
    # param term dominates training at 4k; attention dominates 32k prefill
    assert 6 * n * 4096 * 256 > 3 * attn(256, 4096) * 0.5
    assert attn(32, 32768) > 2 * n * 32768 * 32 * 0.5


def test_strict_mode_raises_on_unmatched_path():
    with pytest.raises(ValueError, match="no sharding rule matches"):
        spec_for_param("unknown/thing", 2, strict=True)
    # lenient default unchanged
    assert spec_for_param("unknown/thing", 2) == P()


@pytest.mark.parametrize("arch", [
    "rwkv6-7b", "seamless-m4t-medium", "zamba2-2.7b", "stablelm-1.6b",
    "llama3-8b", "yi-34b", "gemma2-27b", "deepseek-moe-16b",
    "granite-moe-1b-a400m", "llava-next-34b"])
def test_rule_table_covers_every_config_family(arch):
    """Strict mode must accept every parameter path of all 10 model
    families — full rule coverage, no silent replication anywhere."""
    from repro.configs import ARCHS, get_config
    from repro.models import build_model
    from repro.sharding.rules import _flatten_with_paths

    assert arch in ARCHS                 # the ids above track the registry
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    flat, _ = _flatten_with_paths(params)
    assert flat
    for path, leaf in flat:
        spec = spec_for_param(path, leaf.ndim, strict=True)  # must not raise
        assert len(spec) <= leaf.ndim, (path, spec)


def test_host_mesh_insufficient_devices_names_flag():
    from repro.launch.mesh import make_host_mesh
    n = len(jax.devices())
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        make_host_mesh(data=n, model=2)
    # default shape stays the historical (n, 1)
    mesh = make_host_mesh()
    assert dict(zip(mesh.axis_names, mesh.devices.shape)) == \
        {"data": n, "model": 1}


def test_production_mesh_requires_512_devices():
    """On this 1-device test process the production mesh must refuse —
    proving the dry-run's device-count env is NOT leaking into tests."""
    from repro.launch.mesh import make_production_mesh
    if len(jax.devices()) < 256:
        with pytest.raises(ValueError):
            make_production_mesh()
