"""Solver correctness: brute-force optimality, certificates, constraints."""
import numpy as np
import pytest

from repro.core import (Gemm, Mapping, TEMPLATES, solve, verify,
                        verify_by_enumeration)
from repro.core.certificate import check_constraints, objective_value
from repro.core.geometry import AXES
from repro.core.hardware import AcceleratorSpec, Ert
from repro.core.solver import _axis_energy
from repro.core.energy import analytical_energy

ERT = Ert(dram_read=200.0, dram_write=200.0, sram_read=6.0, sram_write=6.5,
          rf_read=1.0, rf_write=1.1, macc=2.0, sram_leak=0.1,
          rf_leak=0.001)


def tiny_hw(npe, sram, rf, **kw):
    return AcceleratorSpec(name=f"tiny{npe}", sram_words=sram, rf_words=rf,
                           num_pe=npe, ert=ERT, **kw)


CASES = [
    (Gemm(4, 4, 4), tiny_hw(4, 48, 6)),
    (Gemm(4, 6, 4), tiny_hw(4, 64, 8)),
    (Gemm(8, 4, 4), tiny_hw(4, 96, 6, allow_bypass=False)),
    (Gemm(9, 3, 3), tiny_hw(9, 60, 9)),
]


@pytest.mark.parametrize("gemm,hw", CASES)
def test_optimality_vs_enumeration(gemm, hw):
    res = solve(gemm, hw)
    cert = res.certificate
    assert cert.feasible
    assert cert.gap == 0.0
    assert verify(cert, hw)
    assert verify_by_enumeration(cert, hw)


def test_edp_objective_vs_enumeration():
    gemm, hw = Gemm(4, 4, 4), tiny_hw(4, 48, 6, spatial_equality=False)
    res = solve(gemm, hw, objective="edp", spatial_mode="le")
    assert verify(res.certificate, hw)
    assert verify_by_enumeration(res.certificate, hw)


def test_equality_infeasible_falls_back():
    # prime dims cannot fill 4 PEs exactly
    res = solve(Gemm(5, 7, 3), tiny_hw(4, 64, 8))
    assert res.certificate.feasible
    assert res.certificate.spatial_mode == "le"
    assert verify(res.certificate, hw=tiny_hw(4, 64, 8))


def test_fixed_spatial_mxu():
    hw = tiny_hw(16, 4096, 64, fixed_spatial=(4, 4, 1),
                 allow_bypass=False)
    res = solve(Gemm(16, 16, 16), hw)
    assert res.mapping is not None
    assert res.mapping.spatial == (4, 4, 1)


def test_allowed_walk01_restriction():
    gemm, hw = Gemm(8, 8, 8), tiny_hw(4, 96, 8)
    res = solve(gemm, hw, allowed_walk01=("z",))
    assert res.mapping.alpha01 == "z"
    free = solve(gemm, hw)
    assert free.certificate.objective <= res.certificate.objective + 1e-12


def test_vectorized_axis_energy_matches_scalar():
    """The solver's numpy per-axis energies must equal the scalar model."""
    import random
    from repro.core.geometry import divisor_chains
    rng = random.Random(0)
    gemm = Gemm(16, 8, 12)
    hw = tiny_hw(8, 256, 16)
    for _ in range(80):
        chains = [rng.choice(divisor_chains(d)) for d in gemm.dims]
        m = Mapping(
            L1=tuple(c[0] for c in chains), L2=tuple(c[1] for c in chains),
            L3=tuple(c[2] for c in chains),
            alpha01=rng.choice(AXES), alpha12=rng.choice(AXES),
            res1=tuple(rng.random() < 0.7 for _ in range(3)),
            res3=tuple(rng.random() < 0.7 for _ in range(3)))
        total = 0.0
        for i, a in enumerate(AXES):
            g = _axis_energy(a, gemm.dim(a),
                             np.array([m.L1[i]]), np.array([m.L2[i]]),
                             np.array([m.L3[i]]), m.alpha01 == a,
                             m.alpha12 == a, m.res1[i], m.res3[i], hw)
            total += float(g[0])
        bd = analytical_energy(gemm, m, hw)
        assert total + bd.compute == pytest.approx(bd.normalized, rel=1e-9)


def test_objective_value_consistency():
    gemm, hw = Gemm(8, 8, 8), tiny_hw(4, 96, 8)
    res = solve(gemm, hw, objective="edp", spatial_mode="le")
    assert res.certificate.objective == pytest.approx(
        objective_value(gemm, res.mapping, hw, "edp"), rel=1e-9)


def test_constraints_checker():
    gemm = Gemm(8, 8, 8)
    hw = tiny_hw(4, 32, 4)
    ok = Mapping((4, 4, 2), (2, 2, 1), (1, 1, 1), "x", "y")
    assert check_constraints(gemm, ok, hw, spatial_mode="equality")
    too_big_sram = Mapping((8, 8, 8), (2, 2, 1), (1, 1, 1), "x", "y")
    assert not check_constraints(gemm, too_big_sram, hw,
                                 spatial_mode="equality")
    wrong_pe = Mapping((4, 4, 2), (2, 1, 1), (1, 1, 1), "x", "y")
    assert not check_constraints(gemm, wrong_pe, hw,
                                 spatial_mode="equality")
    assert check_constraints(gemm, wrong_pe, hw, spatial_mode="le")


def test_realistic_template_solve_and_verify():
    """One real template x realistic GEMM: solves fast with certificate."""
    hw = TEMPLATES["eyeriss-like"]
    res = solve(Gemm(1024, 2048, 2048), hw)
    cert = res.certificate
    assert cert.feasible and cert.gap == 0.0 and verify(cert, hw)
    assert cert.solve_time_s < 30.0
    assert res.mapping.num_pe_used == hw.num_pe  # eq. 29 at equality
