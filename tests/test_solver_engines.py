"""Differential equality of the two exact-solver engines.

The vectorized frontier engine (core/solver.py, default) must be
*bit-identical* to the reference DFS — same optimum objective, same
mapping, same zero-gap certificate — on every shape: the frontier
engine replays the DFS's incumbent-acceptance sequence exactly, and
this corpus is the gate that keeps that claim honest.  Covers both
objectives, all three spatial modes, bypass on/off, walk restriction,
and warm-start incumbents (valid, exact, and over-tight ones that must
trigger the cold re-solve)."""
import numpy as np
import pytest

from repro.core import Gemm, TEMPLATES
from repro.core.hardware import AcceleratorSpec, Ert
from repro.core.solver import (SolveRequest, axis_cache_stats,
                               clear_axis_cache, solve, solve_many)

ERT = Ert(dram_read=200.0, dram_write=200.0, sram_read=6.0, sram_write=6.5,
          rf_read=1.0, rf_write=1.1, macc=2.0, sram_leak=0.1,
          rf_leak=0.001)


def tiny_hw(npe, sram, rf, **kw):
    return AcceleratorSpec(name=f"tiny{npe}", sram_words=sram, rf_words=rf,
                           num_pe=npe, ert=ERT, **kw)


# (gemm, hw, solve kwargs) — one row per structural feature under test
CORPUS = [
    # objective=energy, spatial equality (paper default)
    (Gemm(4, 4, 4), tiny_hw(4, 48, 6), {}),
    (Gemm(4, 6, 4), tiny_hw(4, 64, 8), {}),
    (Gemm(9, 3, 3), tiny_hw(9, 60, 9), {}),
    (Gemm(64, 48, 36), tiny_hw(16, 2048, 32), {}),
    # allow_bypass off
    (Gemm(8, 4, 4), tiny_hw(4, 96, 6, allow_bypass=False), {}),
    # objective=edp under spatial_mode=le
    (Gemm(4, 4, 4), tiny_hw(4, 48, 6, spatial_equality=False),
     dict(objective="edp", spatial_mode="le")),
    (Gemm(8, 8, 8), tiny_hw(4, 96, 8),
     dict(objective="edp", spatial_mode="le")),
    (Gemm(64, 48, 36), tiny_hw(16, 2048, 32),
     dict(objective="edp", spatial_mode="le")),
    (Gemm(12, 10, 6), tiny_hw(8, 128, 12),
     dict(objective="edp", spatial_mode="le")),
    # equality infeasible (prime dims): documented edp/le fallback
    (Gemm(5, 7, 3), tiny_hw(4, 64, 8), {}),
    # fixed spatial fanout (the TPU/MXU shape of the space)
    (Gemm(16, 16, 16), tiny_hw(16, 4096, 64, fixed_spatial=(4, 4, 1),
                               allow_bypass=False), {}),
    # walking-axis restriction (the Pallas realizability constraint)
    (Gemm(8, 8, 8), tiny_hw(4, 96, 8), dict(allowed_walk01=("z",))),
    # energy objective explicitly under le
    (Gemm(8, 8, 8), tiny_hw(4, 96, 8, spatial_equality=False),
     dict(spatial_mode="le")),
]


def assert_engines_identical(gemm, hw, **kw):
    ref = solve(gemm, hw, engine="reference", **kw)
    vec = solve(gemm, hw, engine="vectorized", **kw)
    cr, cv = ref.certificate, vec.certificate
    assert cr.feasible == cv.feasible
    assert cr.spatial_mode == cv.spatial_mode
    assert cr.objective_kind == cv.objective_kind
    # bit-identical optimum and zero-gap certificate
    assert cr.objective == cv.objective
    assert cr.upper_bound == cv.upper_bound
    assert cr.lower_bound == cv.lower_bound
    if cr.feasible:
        assert cr.gap == 0.0 and cv.gap == 0.0
        assert ref.mapping == vec.mapping
    assert cr.engine == "reference" and cv.engine == "vectorized"
    return ref, vec


@pytest.mark.parametrize("gemm,hw,kw", CORPUS,
                         ids=[f"{g.dims}-{h.name}-{i}"
                              for i, (g, h, kw) in enumerate(CORPUS)])
def test_differential_corpus(gemm, hw, kw):
    assert_engines_identical(gemm, hw, **kw)


def test_differential_realistic_templates():
    """One realistic GEMM per paper template, both objectives."""
    gemm = Gemm(512, 768, 640)
    for name in ("eyeriss-like", "gemmini-like"):
        hw = TEMPLATES[name]
        assert_engines_identical(gemm, hw)
        assert_engines_identical(gemm, hw, objective="edp",
                                 spatial_mode="le")


def test_infeasible_instance_identical():
    # regfile too small for any residency: both engines report infeasible
    hw = tiny_hw(4, 2, 1, allow_bypass=False)
    ref, vec = assert_engines_identical(Gemm(8, 8, 8), hw)
    assert not ref.certificate.feasible
    assert ref.mapping is None and vec.mapping is None


@pytest.mark.parametrize("engine", ["reference", "vectorized"])
def test_warm_start_incumbents(engine):
    gemm, hw = Gemm(8, 8, 8), tiny_hw(4, 96, 8)
    base = solve(gemm, hw, engine=engine)
    opt = base.certificate.objective
    # a valid (loose) incumbent must not change the optimum
    loose = solve(gemm, hw, incumbent=opt * 1.5, engine=engine)
    assert loose.certificate.objective == opt
    assert loose.certificate.warm_started
    # an exact incumbent (re-planning an identical neighbor) still finds it
    exact = solve(gemm, hw, incumbent=opt, engine=engine)
    assert exact.certificate.objective == opt
    # an over-tight incumbent prunes everything -> transparent cold
    # re-solve, same optimum, not marked warm-started
    tight = solve(gemm, hw, incumbent=opt * 0.5, engine=engine)
    assert tight.certificate.objective == opt
    assert not tight.certificate.warm_started


def test_warm_start_cross_engine_identical():
    gemm, hw = Gemm(64, 48, 36), tiny_hw(16, 2048, 32)
    opt = solve(gemm, hw).certificate.objective
    for inc in (opt * 1.25, opt, opt * 0.5):
        assert_engines_identical(gemm, hw, incumbent=inc)


def test_solve_many_shares_axis_cache():
    hw = tiny_hw(16, 2048, 32)
    # shapes sharing the y/z extents, as a scenario sweep does
    reqs = [SolveRequest(gemm=Gemm(m, 48, 36), hw=hw)
            for m in (16, 32, 64, 128)]
    clear_axis_cache()
    results = solve_many(reqs)
    stats = axis_cache_stats()
    assert stats["hits"] > 0          # y/z axes reused across solves
    for r, req in zip(results, reqs):
        one = solve(req.gemm, hw)
        assert one.certificate.objective == r.certificate.objective
        assert one.mapping == r.mapping


def test_engine_recorded_and_default():
    gemm, hw = Gemm(4, 4, 4), tiny_hw(4, 48, 6)
    assert solve(gemm, hw).certificate.engine == "vectorized"
    with pytest.raises(ValueError):
        solve(gemm, hw, engine="nope")


def test_certificate_engine_roundtrips_through_store(tmp_path):
    from repro.planner.store import PlanEntry, PlanStore, plan_key
    gemm, hw = Gemm(4, 4, 4), tiny_hw(4, 48, 6)
    res = solve(gemm, hw)
    key = plan_key(gemm, hw)
    store = PlanStore(tmp_path)
    store.put(PlanEntry.from_solve(key, res.certificate, hw))
    reread = PlanStore(tmp_path).get(key)
    assert reread.certificate.engine == "vectorized"
    assert reread.certificate.objective == res.certificate.objective


def test_random_shapes_fuzz():
    """Randomized differential sweep across shapes/capacities/modes."""
    import random
    rng = random.Random(7)
    dims = [2, 3, 4, 6, 8, 9, 12, 16, 18, 24]
    for _ in range(12):
        gemm = Gemm(rng.choice(dims), rng.choice(dims), rng.choice(dims))
        hw = tiny_hw(rng.choice([4, 8, 16]),
                     rng.choice([64, 256, 1024]),
                     rng.choice([4, 8, 16, 32]),
                     allow_bypass=rng.random() < 0.7)
        kw = ({} if rng.random() < 0.5
              else dict(objective="edp", spatial_mode="le"))
        assert_engines_identical(gemm, hw, **kw)
