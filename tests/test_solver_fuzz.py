"""Randomized engine-equivalence fuzz (hypothesis-driven).

The vectorized frontier engine must be *bit-identical* to the reference
DFS on every instance — the 22-case curated corpus in
tests/test_solver_engines.py pins the structural features; this module
sweeps the cross-product randomly: (Gemm, AcceleratorSpec, objective,
bypass, walk restriction, chain-solver pins) tuples, asserting identical
optimum / mapping / zero-gap certificate.

Two lanes: a small seeded sample in the CI fast lane and a `slow`-marked
deep lane (same strategy, many more examples).  ``derandomize=True``
keeps both reproducible run-to-run (no example database dependence).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Gemm  # noqa: E402
from repro.core.geometry import divisors  # noqa: E402
from repro.core.hardware import AcceleratorSpec, Ert  # noqa: E402
from repro.core.solver import solve  # noqa: E402

ERTS = [
    Ert(dram_read=200.0, dram_write=200.0, sram_read=6.0, sram_write=6.5,
        rf_read=1.0, rf_write=1.1, macc=2.0, sram_leak=0.1, rf_leak=0.001),
    Ert(dram_read=130.0, dram_write=110.0, sram_read=3.1, sram_write=3.4,
        rf_read=0.12, rf_write=0.12, macc=0.55, spatial_reduce=0.05),
]

DIMS = [2, 3, 4, 5, 6, 8, 9, 12, 16, 18, 24]
WALKS = [None, ("z",), ("x",), ("x", "y"), ("y", "z")]


@st.composite
def solve_instance(draw):
    gemm = Gemm(draw(st.sampled_from(DIMS)), draw(st.sampled_from(DIMS)),
                draw(st.sampled_from(DIMS)))
    hw = AcceleratorSpec(
        name="fuzz",
        sram_words=draw(st.sampled_from([48, 96, 256, 1024, 4096])),
        rf_words=draw(st.sampled_from([2, 4, 8, 16, 32])),
        num_pe=draw(st.sampled_from([4, 8, 16])),
        ert=draw(st.sampled_from(ERTS)),
        allow_bypass=draw(st.booleans()),
        spatial_equality=draw(st.booleans()))
    kw = {}
    if draw(st.booleans()):
        kw["objective"] = "edp"
        kw["spatial_mode"] = "le"
    elif draw(st.booleans()):
        kw["spatial_mode"] = "le"
    walk = draw(st.sampled_from(WALKS))
    if walk is not None:
        kw["allowed_walk01"] = walk
    # the chain solver's constraint surface: per-axis L1 pins (drawn from
    # the axis's divisor lattice so the pin is satisfiable) + forced
    # SRAM residency bits
    if draw(st.booleans()):
        kw["fixed_l1"] = tuple(
            draw(st.sampled_from((None,) + divisors(gemm.dims[d])))
            for d in range(3))
    if draw(st.booleans()):
        kw["require_res1"] = tuple(draw(st.booleans()) for _ in range(3))
    return gemm, hw, kw


def assert_engines_identical(gemm, hw, kw):
    ref = solve(gemm, hw, engine="reference", **kw)
    vec = solve(gemm, hw, engine="vectorized", **kw)
    cr, cv = ref.certificate, vec.certificate
    assert cr.feasible == cv.feasible, (gemm, hw, kw)
    assert cr.spatial_mode == cv.spatial_mode
    assert cr.objective_kind == cv.objective_kind
    assert cr.objective == cv.objective, (gemm, hw, kw)
    assert cr.upper_bound == cv.upper_bound
    assert cr.lower_bound == cv.lower_bound
    if cr.feasible:
        assert cr.gap == 0.0 and cv.gap == 0.0
        assert ref.mapping == vec.mapping, (gemm, hw, kw)


@settings(max_examples=25, deadline=None, derandomize=True)
@given(solve_instance())
def test_engine_equivalence_fuzz_fast(instance):
    gemm, hw, kw = instance
    assert_engines_identical(gemm, hw, kw)


@pytest.mark.slow
@settings(max_examples=300, deadline=None, derandomize=True)
@given(solve_instance())
def test_engine_equivalence_fuzz_deep(instance):
    gemm, hw, kw = instance
    assert_engines_identical(gemm, hw, kw)
