"""End-to-end integration on CPU: training descends, checkpoint/restart
resumes exactly, serving engine is deterministic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, host_batch
from repro.models import build_model
from repro.serving import Engine, ServeConfig
from repro.training import LoopConfig, optimizer as opt, run_training
from repro.training.train_step import make_train_step

pytestmark = pytest.mark.slow    # CPU training loops, ~15s


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("llama3-8b", smoke=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100)
    step = jax.jit(make_train_step(model, ocfg, remat=False))
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8,
                          seed=0)
    return cfg, model, params, ocfg, step, data_cfg


def _shardings(data_cfg):
    # host-local single-device "shardings": plain device_put targets
    return {"tokens": jax.devices()[0], "labels": jax.devices()[0]}


def _run(step, params, opt_state, data_cfg, n, start=0):
    losses = []
    for i in range(start, start + n):
        b = host_batch(data_cfg, i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    return params, opt_state, losses


def test_training_descends(tiny_setup):
    cfg, model, params, ocfg, step, data_cfg = tiny_setup
    opt_state = opt.init_state(params)
    _, _, losses = _run(step, params, opt_state, data_cfg, 30)
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_restart_resumes_exactly(tiny_setup, tmp_path):
    cfg, model, params, ocfg, step, data_cfg = tiny_setup
    opt_state = opt.init_state(params)

    # uninterrupted 12 steps
    p_ref, _, losses_ref = _run(step, params, opt_state, data_cfg, 12)

    # interrupted: 6 steps -> checkpoint -> "crash" -> restore -> 6 more
    mgr = CheckpointManager(tmp_path, async_save=False)
    p6, s6, losses_a = _run(step, params, opt.init_state(params),
                            data_cfg, 6)
    mgr.save(6, (p6, s6))
    del p6, s6  # crash
    (p_r, s_r), step0 = mgr.restore(
        jax.eval_shape(lambda: (params, opt.init_state(params))))
    assert step0 == 6
    p_fin, _, losses_b = _run(step, p_r, s_r, data_cfg, 6, start=6)
    np.testing.assert_allclose(losses_a + losses_b, losses_ref,
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(p_fin), jax.tree.leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)


def test_run_training_loop_with_watchdog(tiny_setup, tmp_path):
    cfg, model, params, ocfg, step, data_cfg = tiny_setup

    def step_arrays(params, opt_state, batch):
        return step(params, opt_state,
                    {k: jnp.asarray(v) for k, v in batch.items()})

    # monkey-patch global_arrays-compatible shardings: run_training calls
    # data.global_arrays; emulate with host-local batches via a tiny shim
    from repro.training import loop as loop_mod
    orig = loop_mod.global_arrays
    loop_mod.global_arrays = (
        lambda cfg_, s, _sh: {k: jnp.asarray(v)
                              for k, v in host_batch(cfg_, s).items()})
    try:
        mgr = CheckpointManager(tmp_path, async_save=False)
        _, _, state = run_training(
            step_arrays, params, opt.init_state(params), data_cfg, None,
            LoopConfig(total_steps=8, ckpt_every=4, log_every=100),
            mgr, log=lambda s: None)
        assert state.step == 8
        assert mgr.latest_step() == 8
        # restart picks up from the final checkpoint and does nothing
        _, _, state2 = run_training(
            step_arrays, params, opt.init_state(params), data_cfg, None,
            LoopConfig(total_steps=8), mgr, log=lambda s: None)
        assert state2.step == 8 and not state2.losses
    finally:
        loop_mod.global_arrays = orig


def test_serving_engine_deterministic(tiny_setup):
    cfg, model, params, *_ = tiny_setup
    eng = Engine(model, params, ServeConfig(max_new_tokens=8,
                                            cache_len=64))
    prompts = np.array([[1, 2, 3, 4], [7, 8, 9, 10]], np.int32)
    out1 = eng.generate(prompts)
    out2 = eng.generate(prompts)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 8)
    assert (out1 >= 0).all() and (out1 < cfg.vocab).all()


def test_serving_engine_stop_token(tiny_setup):
    """Device-side done/fill bookkeeping: a row that hits the stop token
    keeps its greedy prefix and is stop-token-padded afterwards, while a
    row that never stops decodes exactly as without a stop token (done
    only masks the output write, not the decode input)."""
    cfg, model, params, *_ = tiny_setup
    prompts = np.array([[1, 2, 3, 4], [7, 8, 9, 10]], np.int32)
    base = Engine(model, params, ServeConfig(
        max_new_tokens=8, cache_len=64)).generate(prompts)
    k = 2
    stop = int(base[0, k])      # force row 0 to finish at step k
    assert stop not in base[1]  # row 1 must run the full budget
    out = Engine(model, params, ServeConfig(
        max_new_tokens=8, cache_len=64,
        stop_token=stop)).generate(prompts)
    # row 0: unchanged greedy prefix, then stop-token padding
    np.testing.assert_array_equal(out[0, :k], base[0, :k])
    assert (out[0, k:] == stop).all(), out[0]
    # row 1 never stops -> no early exit, bit-identical decode
    np.testing.assert_array_equal(out[1], base[1])


def test_grad_compression_numerics():
    """Error-feedback int8 all-reduce approximates the exact mean and the
    residual shrinks the bias across steps."""
    from jax.sharding import Mesh
    from repro.training.grad_compression import (
        init_error_buffers, make_compressed_allreduce)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    reduce = make_compressed_allreduce(mesh, axis_names=("data",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1, 64, 64))}
    errs = init_error_buffers(g)
    out, errs = reduce(g, errs)
    exact = g["w"]  # single replica: mean == itself
    err = float(jnp.max(jnp.abs(out["w"] - exact)))
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert err <= scale * 1.01  # one quantization step
    # error buffer carries exactly the quantization residual
    out2, errs2 = reduce(g, errs)
    # with feedback, the running average of outputs approaches exact
    avg = (out["w"] + out2["w"]) / 2
    assert float(jnp.max(jnp.abs(avg - exact))) <= err
