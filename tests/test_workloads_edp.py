"""Workload extraction + EDP accounting."""
import pytest

from repro.core import Gemm, Mapping, TEMPLATES, evaluate
from repro.core.edp import EdpReport
from repro.core.workloads import (GEMM_TYPES, LLAMA32_1B, QWEN3_32B,
                                  arch_gemms, paper_cases, prefill_gemms)


def test_prefill_gemm_types_and_weights():
    gs = prefill_gemms(LLAMA32_1B, 1024)
    types = [t for t, _, _ in gs]
    assert types == list(GEMM_TYPES)
    w = dict((t, w) for t, _, w in gs)
    L, H = LLAMA32_1B.layers, LLAMA32_1B.n_heads
    assert w["attn_q_proj"] == L
    assert w["attn_kv_proj"] == 2 * L
    assert w["attn_score"] == L * H
    assert w["mlp_gate_up"] == 2 * L
    assert w["lm_head"] == 1
    # lm_head is matrix-vector (paper Fig. 7 discussion)
    lm = [g for t, g, _ in gs if t == "lm_head"][0]
    assert lm.Lx == 1 and lm.Ly == LLAMA32_1B.vocab


def test_paper_cases_count():
    cases = paper_cases()
    assert len(cases) == 24
    # 12 edge on 2 edge templates + 12 center on 2 center templates
    assert sum("eyeriss" in c[3] or "gemmini" in c[3] for c in cases) == 12


def test_gemm_shapes_scale_with_seq():
    g1 = dict((t, g) for t, g, _ in prefill_gemms(QWEN3_32B, 2048))
    g2 = dict((t, g) for t, g, _ in prefill_gemms(QWEN3_32B, 131072))
    assert g2["attn_score"].Lx == 64 * g1["attn_score"].Lx
    assert g2["mlp_down"].Ly == g1["mlp_down"].Ly  # N fixed


@pytest.mark.parametrize("arch", ["rwkv6-7b", "zamba2-2.7b",
                                  "deepseek-moe-16b", "llama3-8b"])
def test_arch_gemm_extraction(arch):
    gs = arch_gemms(arch, seq=1024)
    assert gs, arch
    types = {t for t, _, _ in gs}
    assert "lm_head" in types
    if arch == "rwkv6-7b":
        assert "attn_score" not in types      # attention-free
        assert "rwkv_time_mix" in types
    if arch == "zamba2-2.7b":
        assert "ssm_in_proj" in types and "attn_score" in types
    if arch == "deepseek-moe-16b":
        assert "mlp_gate_up" in types


def test_edp_report_and_aggregation():
    hw = TEMPLATES["eyeriss-like"]
    gemm = Gemm(64, 64, 64)
    m = Mapping((32, 32, 32), (16, 16, 1), (1, 1, 1), "z", "z")
    rep = evaluate(gemm, m, hw)
    assert rep.num_pe_used == 256
    # roofline delay: at least the compute bound, exactly the max over
    # the per-level bandwidth terms (checked in detail in test_pareto)
    assert rep.delay_ns >= gemm.volume / 256 * hw.cycle_ns
    assert rep.edp == pytest.approx(
        rep.energy_pj * 1e-12 * rep.delay_ns * 1e-9)
    # with no bandwidth table entry the compute-only bound is recovered
    import dataclasses
    free = dataclasses.replace(hw, name="unlisted")
    rep_free = evaluate(gemm, m, free)
    assert rep_free.delay_ns == pytest.approx(
        gemm.volume / 256 * hw.cycle_ns)


def test_edp_aggregate_sequential_semantics():
    """Aggregates are self-consistent: edp == E*T under the sequential
    schedule, the paper's Σ w·EDPᵢ lives under a distinct name, and the
    old num_pe_used=0 sentinel is gone."""
    hw = TEMPLATES["eyeriss-like"]
    gemm = Gemm(64, 64, 64)
    m = Mapping((32, 32, 32), (16, 16, 1), (1, 1, 1), "z", "z")
    rep = evaluate(gemm, m, hw)
    assert not rep.is_aggregate and rep.weighted_edp_sum is None
    agg = EdpReport.aggregate([(rep, 3)])
    assert agg.energy_pj == pytest.approx(3 * rep.energy_pj)
    assert agg.delay_ns == pytest.approx(3 * rep.delay_ns)
    # derived, self-consistent: (3E)·(3T) = 9·E·T — not the old Σ w·EDP
    assert agg.edp == pytest.approx(
        agg.energy_pj * 1e-12 * agg.delay_ns * 1e-9)
    assert agg.edp == pytest.approx(9 * rep.edp)
    # the paper's Table II scalar is preserved under its own name
    assert agg.weighted_edp_sum == pytest.approx(3 * rep.edp)
    # sentinel gone: no consumer can divide by a fake PE count
    assert agg.num_pe_used is None and agg.is_aggregate
